//! The training loop: drives a `LoadedModel` over a `Dataset` with a
//! precision `Schedule` — the L3 hot path.
//!
//! Per chunk of K optimizer steps:
//!   1. ask the precision policy for q_fwd[K] (integer-rounded
//!      bit-widths) — a [`crate::policy::StaticPolicy`] replays the CPT
//!      schedule exactly as the pre-policy trainer did; adaptive policies
//!      choose from the feedback of step 6,
//!   2. evaluate the LR schedule  -> lr[K],
//!   3. assemble K minibatches into arena scratch (stacked) + shared
//!      inputs (converted to literals once per run when the dataset marks
//!      them static),
//!   4. one PJRT call on the train-chunk executable (state uploaded from
//!      cached host vectors — no clone_literal roundtrips),
//!   5. account BitOps (exact realized trace: mean q and relative cost
//!      land in the History), record history, run periodic eval
//!      (eval-batch literals also cached across evals for static
//!      datasets),
//!   6. feed the chunk's loss signals back to the policy
//!      ([`crate::policy::ChunkFeedback`]) — the input to the next
//!      chunk's precision decision.
//!
//! Python is never involved; the schedule decisions (the paper's
//! contribution) and the policy feedback loop (rust/DESIGN-policy.md)
//! all happen here. Caching invariants are documented in
//! rust/DESIGN-perf.md.

pub mod checkpoint;
pub mod lr;

pub use lr::LrSchedule;

use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::data::Dataset;
use crate::metrics::History;
use crate::obs::trace::{self, Event};
use crate::policy::{ChunkFeedback, PrecisionPolicy, StaticPolicy};
use crate::quant::BitOpsAccountant;
use crate::runtime::{HostTensor, LiteralArena, LoadedModel, TrainState};
use crate::schedule::Schedule;
use crate::util::prng::Pcg32;

/// Configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub total_steps: usize,
    /// Backward precision (pinned to q_max per paper §3.1).
    pub q_bwd: f32,
    /// Evaluate every this many steps (0 = only at the end).
    pub eval_every: usize,
    /// PRNG seed for the run (init seed + per-step dropout seeds).
    pub seed: i32,
    /// Log train loss every this many steps into History (1 = all).
    pub log_every: usize,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            total_steps: 200,
            q_bwd: 8.0,
            eval_every: 0,
            seed: 0,
            log_every: 1,
            verbose: false,
        }
    }
}

/// Trainer: owns the run state and produces a History.
pub struct Trainer<'m, 'd> {
    pub model: &'m LoadedModel,
    pub data: &'d mut dyn Dataset,
    /// Precision decision process: [`StaticPolicy`] for schedule-driven
    /// runs (the paper's path), adaptive policies otherwise.
    pub policy: Box<dyn PrecisionPolicy>,
    pub lr: LrSchedule,
    pub cfg: TrainConfig,
    /// Reusable scratch for stacked-minibatch assembly (one slot per
    /// stacked model input).
    arena: LiteralArena,
    /// Reusable per-chunk batch rows (outer Vec reused across chunks).
    rows: Vec<Vec<HostTensor>>,
    /// Shared-input literals; rebuilt per chunk unless the dataset is
    /// static, in which case they are built exactly once per run.
    shared_lits: Vec<Literal>,
    shared_built: bool,
    /// Cached eval-batch literals (static datasets only), lazily built
    /// on first evaluation of each batch index.
    eval_cache: Vec<Option<Vec<Literal>>>,
    remainder_noted: bool,
}

impl<'m, 'd> Trainer<'m, 'd> {
    /// Schedule-driven trainer — the legacy constructor; the schedule is
    /// wrapped in a [`StaticPolicy`], whose chunked emission is
    /// propcheck-identical to `Schedule::q_vec`, so this path reproduces
    /// the pre-policy trainer bit for bit.
    pub fn new(
        model: &'m LoadedModel,
        data: &'d mut dyn Dataset,
        schedule: Schedule,
        lr: LrSchedule,
        cfg: TrainConfig,
    ) -> Self {
        Self::with_policy(
            model,
            data,
            Box::new(StaticPolicy::new(schedule)),
            lr,
            cfg,
        )
    }

    /// Policy-driven trainer: precision is chosen per chunk from training
    /// feedback.
    pub fn with_policy(
        model: &'m LoadedModel,
        data: &'d mut dyn Dataset,
        policy: Box<dyn PrecisionPolicy>,
        lr: LrSchedule,
        cfg: TrainConfig,
    ) -> Self {
        Trainer {
            model,
            data,
            policy,
            lr,
            cfg,
            arena: LiteralArena::new(),
            rows: Vec::new(),
            shared_lits: Vec::new(),
            shared_built: false,
            eval_cache: Vec::new(),
            remainder_noted: false,
        }
    }

    /// Run the full training loop, returning the history.
    pub fn run(&mut self) -> Result<History> {
        let t_start = Instant::now();
        let mut state = self.model.init_state(self.cfg.seed)?;
        let mut hist = History::default();
        let mut acc = BitOpsAccountant::new(
            &self.model.spec,
            self.cfg.q_bwd as f64,
            self.data.agg_density(),
        );
        let mut seed_rng = Pcg32::new(self.cfg.seed as u64, 0x5EED);

        let chunk = self.model.spec.chunk;
        let total = self.cfg.total_steps;
        let mut step = 0usize;
        let mut exec_s = 0.0f64;

        while step < total {
            let k = chunk.min(total - step);
            // the chunk executable is fixed at K; use K or fall back to
            // k=1 remainder steps
            let k = if k == chunk { chunk } else { 1 };
            if k != chunk && !self.remainder_noted {
                self.remainder_noted = true;
                // one line per run, and only when this run is verbose —
                // parallel sweep workers run quiet (their stderr would
                // interleave across threads)
                if self.cfg.verbose {
                    crate::log_info!(
                        "[train {}] total_steps {total} not a multiple of chunk {chunk} — running the last {} step(s) via the k=1 artifact",
                        self.model.spec.name,
                        total - step,
                    );
                }
            }

            let q_fwd = self.policy.q_chunk(step, k);
            debug_assert_eq!(q_fwd.len(), k);
            let lr_v: Vec<f32> =
                (step..step + k).map(|t| self.lr.at(t)).collect();
            let seeds: Vec<i32> =
                (0..k).map(|_| seed_rng.next_u32() as i32).collect();

            let stacked = self.stacked_inputs(step, k)?;
            self.ensure_shared(step)?;

            let t0 = Instant::now();
            let res = self.model.advance(
                &mut state,
                k,
                &stacked,
                &self.shared_lits,
                &q_fwd,
                &lr_v,
                &seeds,
                self.cfg.q_bwd,
            )?;
            let chunk_s = t0.elapsed().as_secs_f64();
            exec_s += chunk_s;

            if trace::enabled() {
                // worker/member/cell inherited from the thread's cell
                // context; the executor flushes at the cell boundary
                let mean_q =
                    q_fwd.iter().map(|&q| q as f64).sum::<f64>() / k as f64;
                trace::emit(
                    Event::new(trace::now() - chunk_s, "chunk")
                        .dur(chunk_s)
                        .tag_num("step", step as f64)
                        .tag_num("k", k as f64)
                        .tag_num("q_t", q_fwd[k - 1] as f64)
                        .tag_num("mean_q", mean_q)
                        .tag_num("loss", res.losses[k - 1] as f64),
                );
            }

            acc.record_steps(&q_fwd);
            for (i, (&l, &m)) in
                res.losses.iter().zip(res.metrics.iter()).enumerate()
            {
                let t = step + i;
                if t % self.cfg.log_every == 0 {
                    hist.losses.push((t, l));
                    hist.metrics.push((t, m));
                    hist.precisions.push((t, q_fwd[i] as u32));
                }
            }
            // plateau-style LR schedules need feedback
            self.lr.observe_loss(step + k, res.losses[k - 1]);
            // ... and so do adaptive precision policies: the executed
            // chunk's loss signals drive the next chunk's q_t
            self.policy
                .observe(ChunkFeedback::from_losses(step, &res.losses));

            step += k;

            if self.cfg.eval_every > 0
                && (step % self.cfg.eval_every == 0 || step >= total)
            {
                let (el, em) = self.evaluate(&state)?;
                hist.evals.push((step, el, em));
                if self.cfg.verbose {
                    crate::log_info!(
                        "[train {}] step {step}/{total} q={} loss={:.4} eval_loss={el:.4} eval_metric={em:.4}",
                        self.model.spec.name,
                        q_fwd[k - 1],
                        res.losses[k - 1],
                    );
                }
            }
        }

        if self.cfg.eval_every == 0 {
            let (el, em) = self.evaluate(&state)?;
            hist.evals.push((step, el, em));
        }

        hist.gbitops = acc.total().gbitops;
        hist.mean_q = acc.realized_mean_q();
        hist.realized_cost = acc.realized_relative_cost();
        hist.exec_seconds = exec_s;
        hist.total_seconds = t_start.elapsed().as_secs_f64();
        Ok(hist)
    }

    /// Mean eval loss/metric over the dataset's eval batches. For static
    /// datasets the batch literals are built once and reused across all
    /// evaluation points in the run.
    pub fn evaluate(&mut self, state: &TrainState) -> Result<(f32, f32)> {
        let n = self.data.eval_batches();
        let cacheable = self.data.shared_static();
        if cacheable && self.eval_cache.len() != n {
            self.eval_cache = (0..n).map(|_| None).collect();
        }
        // upload the (large) params tensor once for all eval batches
        let params = state.params.to_literal()?;
        let mut sl = 0.0f32;
        let mut sm = 0.0f32;
        for i in 0..n {
            let (l, m) = if cacheable {
                if self.eval_cache[i].is_none() {
                    let batch = self.data.eval_batch(i)?;
                    self.eval_cache[i] = Some(to_literals(&batch)?);
                }
                let lits = self.eval_cache[i].as_ref().unwrap();
                self.model.evaluate_prepared(&params, lits)?
            } else {
                let batch = self.data.eval_batch(i)?;
                let lits = to_literals(&batch)?;
                self.model.evaluate_prepared(&params, &lits)?
            };
            sl += l;
            sm += m;
        }
        Ok((sl / n as f32, sm / n as f32))
    }

    /// Build the stacked literals for a k-step chunk at `step`, writing
    /// the stacked buffers into reusable arena scratch memory.
    fn stacked_inputs(&mut self, step: usize, k: usize) -> Result<Vec<Literal>> {
        self.rows.clear();
        for i in 0..k {
            let batch = self.data.train_batch(step + i)?;
            if let Some(first) = self.rows.first() {
                if batch.len() != first.len() {
                    bail!(
                        "train_batch({}) returned {} tensors, expected {}",
                        step + i,
                        batch.len(),
                        first.len()
                    );
                }
            }
            self.rows.push(batch);
        }
        let n_slots = self.rows.first().map(|r| r.len()).unwrap_or(0);
        let rows = &self.rows;
        let arena = &mut self.arena;
        let mut stacked = Vec::with_capacity(n_slots);
        for j in 0..n_slots {
            let parts: Vec<&HostTensor> = rows.iter().map(|r| &r[j]).collect();
            stacked.push(
                arena
                    .stack_literal(j, &parts)
                    .with_context(|| format!("stacking input slot {j}"))?,
            );
        }
        Ok(stacked)
    }

    /// Convert shared inputs to literals — once per run for static
    /// datasets (e.g. the GNN adjacency), per chunk otherwise (e.g.
    /// SAGE neighbor re-sampling).
    fn ensure_shared(&mut self, step: usize) -> Result<()> {
        if self.shared_built && self.data.shared_static() {
            return Ok(());
        }
        let shared = self.data.shared_inputs(step)?;
        self.shared_lits = to_literals(&shared).context("shared inputs")?;
        self.shared_built = true;
        Ok(())
    }
}

fn to_literals(ts: &[HostTensor]) -> Result<Vec<Literal>> {
    ts.iter().map(|t| t.to_literal()).collect()
}

//! Structured span/event tracing with a durable JSONL sink.
//!
//! An [`Event`] is one timestamped record — a point event or a span
//! (when `dur` is set) — tagged with the worker/member/cell coordinates
//! it happened at plus free-form key/value tags (model fingerprints,
//! cache outcomes, q_t values). Events serialize one-per-line as
//! compact JSON (the encoder escapes newlines, so a line is always one
//! event) into `<root>/trace/trace-<pid>.jsonl`.
//!
//! Overhead contract (see rust/DESIGN-obs.md):
//!
//! * **Off by default.** Without [`install`] (the `--trace` flag),
//!   [`enabled`] is one `OnceLock::get` and every emit is a no-op —
//!   nothing is formatted, allocated, or locked.
//! * **Per-thread buffers.** [`emit`] pushes onto a `thread_local` Vec;
//!   no lock, no I/O. The sink is only touched by [`flush`], which
//!   workers call at cell boundaries — never inside the train loop.
//! * **Result-inert.** Tracing writes only under `<root>/trace/`;
//!   manifests, artifacts, and CSVs are byte-identical with tracing on
//!   or off (gated in scripts/check.sh).
//!
//! Crash tolerance: a process killed mid-write leaves at most one
//! truncated tail line per file; [`read_file`] skips unparsable lines
//! instead of failing, so `cpt trace` always works on a dead run's
//! directory. Timestamps come from an injectable
//! [`Clock`](crate::coordinator::lease::Clock) so tests fabricate
//! deterministic timelines.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{Context as _, Result};

use crate::coordinator::lease::{Clock, SystemClock};
use crate::util::json::{self, Json};

/// One trace record. `t` is seconds on the tracer's clock (UNIX epoch
/// in production, fabricated in tests); `dur` turns the event into a
/// span of that many seconds ending at emit time semantics are up to
/// the emitter — this module only records what it is given.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub t: f64,
    pub kind: String,
    pub dur: Option<f64>,
    pub worker: Option<usize>,
    pub member: Option<usize>,
    pub cell: Option<usize>,
    pub tags: BTreeMap<String, Json>,
}

impl Event {
    pub fn new(t: f64, kind: &str) -> Event {
        Event {
            t,
            kind: kind.to_string(),
            dur: None,
            worker: None,
            member: None,
            cell: None,
            tags: BTreeMap::new(),
        }
    }

    pub fn dur(mut self, seconds: f64) -> Event {
        self.dur = Some(seconds);
        self
    }

    pub fn worker(mut self, w: usize) -> Event {
        self.worker = Some(w);
        self
    }

    pub fn member(mut self, m: usize) -> Event {
        self.member = Some(m);
        self
    }

    pub fn cell(mut self, c: usize) -> Event {
        self.cell = Some(c);
        self
    }

    pub fn tag(mut self, key: &str, value: Json) -> Event {
        self.tags.insert(key.to_string(), value);
        self
    }

    pub fn tag_str(self, key: &str, value: &str) -> Event {
        self.tag(key, json::s(value))
    }

    pub fn tag_num(self, key: &str, value: f64) -> Event {
        self.tag(key, json::num(value))
    }

    /// Tag accessor: string value or "" when absent/not a string.
    pub fn tag_as_str(&self, key: &str) -> &str {
        match self.tags.get(key) {
            Some(Json::Str(s)) => s,
            _ => "",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("t".to_string(), json::num(self.t));
        m.insert("kind".to_string(), json::s(&self.kind));
        if let Some(d) = self.dur {
            m.insert("dur".to_string(), json::num(d));
        }
        if let Some(w) = self.worker {
            m.insert("worker".to_string(), json::num(w as f64));
        }
        if let Some(mi) = self.member {
            m.insert("member".to_string(), json::num(mi as f64));
        }
        if let Some(c) = self.cell {
            m.insert("cell".to_string(), json::num(c as f64));
        }
        if !self.tags.is_empty() {
            m.insert("tags".to_string(), Json::Obj(self.tags.clone()));
        }
        Json::Obj(m)
    }

    /// One compact JSONL line (no raw newline — the encoder escapes
    /// them inside strings).
    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json(v: &Json) -> Result<Event> {
        let t = v.get("t")?.as_f64()?;
        let kind = v.get("kind")?.as_str()?.to_string();
        let mut ev = Event::new(t, &kind);
        if let Some(d) = v.opt("dur") {
            ev.dur = Some(d.as_f64()?);
        }
        if let Some(w) = v.opt("worker") {
            ev.worker = Some(w.as_usize()?);
        }
        if let Some(m) = v.opt("member") {
            ev.member = Some(m.as_usize()?);
        }
        if let Some(c) = v.opt("cell") {
            ev.cell = Some(c.as_usize()?);
        }
        if let Some(tags) = v.opt("tags") {
            ev.tags = tags.as_obj()?.clone();
        }
        Ok(ev)
    }

    pub fn parse_line(line: &str) -> Result<Event> {
        Event::from_json(&Json::parse(line)?)
    }
}

/// The durable sink: one append-mode JSONL file per process under
/// `<root>/trace/`, plus an atomically written `meta-<pid>.json`
/// recording the schema version (the one place the atomic-write util
/// applies — event lines are appended, which is inherently sequential).
pub struct Tracer {
    clock: Arc<dyn Clock>,
    sink: Mutex<std::io::BufWriter<std::fs::File>>,
    path: PathBuf,
}

/// Trace schema version, recorded in each writer's meta file.
pub const TRACE_VERSION: usize = 1;

impl Tracer {
    /// Open a sink under `<root>/trace/` with the given clock.
    pub fn create(root: &Path, clock: Arc<dyn Clock>) -> Result<Arc<Tracer>> {
        let dir = root.join("trace");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let pid = std::process::id();
        json::obj(vec![
            ("version", json::num(TRACE_VERSION as f64)),
            ("pid", json::num(pid as f64)),
        ])
        .write_atomic(dir.join(format!("meta-{pid}.json")))?;
        let path = dir.join(format!("trace-{pid}.jsonl"));
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open {}", path.display()))?;
        Ok(Arc::new(Tracer {
            clock,
            sink: Mutex::new(std::io::BufWriter::new(file)),
            path,
        }))
    }

    /// [`Tracer::create`] on the system clock — the production path.
    pub fn create_system(root: &Path) -> Result<Arc<Tracer>> {
        Tracer::create(root, Arc::new(SystemClock))
    }

    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append a batch of events as JSONL and flush to the OS. Tracing
    /// is best-effort by contract: an I/O failure warns once and drops
    /// events rather than failing the run it observes.
    pub fn append(&self, events: &[Event]) {
        if events.is_empty() {
            return;
        }
        static WARNED: AtomicBool = AtomicBool::new(false);
        let mut sink = self.sink.lock().unwrap();
        let res = (|| -> std::io::Result<()> {
            for ev in events {
                sink.write_all(ev.to_line().as_bytes())?;
                sink.write_all(b"\n")?;
            }
            sink.flush()
        })();
        if let Err(e) = res {
            if !WARNED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "[trace] note: dropping trace events ({}: {e}); the run \
                     itself is unaffected",
                    self.path.display()
                );
            }
        }
    }
}

// ---- process-global tracer + per-thread buffers ---------------------------

static TRACER: OnceLock<Arc<Tracer>> = OnceLock::new();

/// Install the process tracer (the `--trace` flag). First caller wins;
/// returns whether this call installed it.
pub fn install(tracer: Arc<Tracer>) -> bool {
    TRACER.set(tracer).is_ok()
}

/// Cheap hot-path gate: is a tracer installed?
pub fn enabled() -> bool {
    TRACER.get().is_some()
}

/// Seconds on the installed tracer's clock (0.0 when tracing is off —
/// callers always gate on [`enabled`] first).
pub fn now() -> f64 {
    TRACER.get().map_or(0.0, |t| t.now())
}

#[derive(Clone, Copy, Default)]
struct Ctx {
    worker: Option<usize>,
    member: Option<usize>,
    cell: Option<usize>,
}

thread_local! {
    static CTX: std::cell::Cell<Ctx> = std::cell::Cell::new(Ctx::default());
    static BUF: std::cell::RefCell<Vec<Event>> =
        std::cell::RefCell::new(Vec::new());
}

/// Pin this thread's cell coordinates; events emitted here (including
/// from the trainer running inside `run_cell`) inherit them unless set
/// explicitly. Workers call this right after claiming a cell.
pub fn set_cell_ctx(worker: usize, member: usize, cell: usize) {
    CTX.with(|c| {
        c.set(Ctx {
            worker: Some(worker),
            member: Some(member),
            cell: Some(cell),
        })
    });
}

pub fn clear_cell_ctx() {
    CTX.with(|c| c.set(Ctx::default()));
}

/// Buffer one event on this thread (no lock, no I/O). Missing
/// worker/member/cell fields are filled from the thread's cell context;
/// fields the caller set explicitly win. No-op when tracing is off.
pub fn emit(mut ev: Event) {
    if !enabled() {
        return;
    }
    let ctx = CTX.with(|c| c.get());
    ev.worker = ev.worker.or(ctx.worker);
    ev.member = ev.member.or(ctx.member);
    ev.cell = ev.cell.or(ctx.cell);
    BUF.with(|b| b.borrow_mut().push(ev));
}

/// Drain this thread's buffer into the sink. Workers call this at cell
/// boundaries; collectors after recording; the CLI before exit.
pub fn flush() {
    let Some(tracer) = TRACER.get() else { return };
    let events = BUF.with(|b| std::mem::take(&mut *b.borrow_mut()));
    tracer.append(&events);
}

// ---- readers --------------------------------------------------------------

/// Parse one JSONL trace file, skipping lines that don't parse (the
/// truncated tail a crash leaves, or foreign garbage) — never fatal.
pub fn read_file(path: &Path) -> Result<Vec<Event>> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut out = Vec::new();
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Ok(ev) = Event::parse_line(line) {
            out.push(ev);
        }
    }
    Ok(out)
}

/// All events under a root's `trace/` dir (or the dir itself when
/// `root` already ends in trace files), files in name order, events
/// sorted by timestamp. An absent directory is an empty trace.
pub fn read_root(root: &Path) -> Result<Vec<Event>> {
    let dir = if root.join("trace").is_dir() {
        root.join("trace")
    } else {
        root.to_path_buf()
    };
    if !dir.is_dir() {
        return Ok(Vec::new());
    }
    let mut files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .with_context(|| format!("read dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().and_then(|x| x.to_str()) == Some("jsonl")
        })
        .collect();
    files.sort();
    let mut events = Vec::new();
    for f in files {
        events.extend(read_file(&f)?);
    }
    events.sort_by(|a, b| {
        a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal)
    });
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lease::TestClock;

    #[test]
    fn event_json_round_trips_with_all_fields() {
        let ev = Event::new(12.5, "compile")
            .dur(0.75)
            .worker(3)
            .member(1)
            .cell(7)
            .tag_str("fp", "abc123")
            .tag_num("q_t", 8.0);
        let back = Event::parse_line(&ev.to_line()).unwrap();
        assert_eq!(ev, back);
    }

    #[test]
    fn event_line_never_contains_raw_newline() {
        let ev = Event::new(0.0, "note").tag_str("msg", "a\nb\r\tc\u{1}");
        let line = ev.to_line();
        assert!(!line.contains('\n'), "{line}");
        assert_eq!(Event::parse_line(&line).unwrap(), ev);
    }

    #[test]
    fn tracer_appends_and_reader_skips_truncated_tail() {
        let dir = std::env::temp_dir().join("cpt_trace_sink_test");
        std::fs::remove_dir_all(&dir).ok();
        let clock = Arc::new(TestClock::new(100.0));
        let tracer = Tracer::create(&dir, clock.clone()).unwrap();
        tracer.append(&[
            Event::new(tracer.now(), "a").worker(0),
            Event::new(tracer.now(), "b").worker(1).dur(0.5),
        ]);
        // simulate a crash mid-line: append a truncated record
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(tracer.path())
                .unwrap();
            f.write_all(b"{\"t\":101,\"kind\":\"tru").unwrap();
        }
        let events = read_root(&dir).unwrap();
        assert_eq!(events.len(), 2, "{events:?}");
        assert_eq!(events[0].kind, "a");
        assert_eq!(events[1].dur, Some(0.5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_root_on_missing_dir_is_empty() {
        let dir = std::env::temp_dir().join("cpt_trace_missing_test");
        std::fs::remove_dir_all(&dir).ok();
        assert!(read_root(&dir).unwrap().is_empty());
    }
}

//! Trace analyzer: rebuild per-worker timelines from a run's JSONL
//! trace (`cpt trace DIR`).
//!
//! The executor emits four span kinds per cell — `claim` (time spent
//! blocked waiting for a claimable cell), `compile`, `exec`, and
//! `record` — all carrying worker/member/cell coordinates. This module
//! folds them into the answers the ISSUE motivates: where did the wall
//! clock of a campaign go, per worker and per member, and which cells
//! were slowest. Everything else in the trace (trainer `chunk` events,
//! lease/daemon events) is counted by kind but not broken down here.

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

use super::trace::Event;

/// Per-worker wall-clock breakdown in seconds.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerBreakdown {
    pub worker: usize,
    pub cells: usize,
    pub queue_wait: f64,
    pub compile: f64,
    pub exec: f64,
    pub record: f64,
}

impl WorkerBreakdown {
    /// Accounted wall seconds: the sum of the four span kinds. For a
    /// healthy trace this agrees with the worker's busy wall clock
    /// within tolerance (the gap is claim-loop bookkeeping).
    pub fn total(&self) -> f64 {
        self.queue_wait + self.compile + self.exec + self.record
    }
}

/// Per-member compile/exec totals.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemberBreakdown {
    pub member: usize,
    /// Display label from the first exec event's `name`/`model` tag.
    pub label: String,
    pub cells: usize,
    pub compile: f64,
    pub exec: f64,
}

/// One of the top-k slowest cells (compile + exec seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct SlowCell {
    pub member: usize,
    pub cell: usize,
    pub worker: Option<usize>,
    pub seconds: f64,
}

/// The folded trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSummary {
    pub events: usize,
    /// Event counts by kind, sorted by kind name.
    pub kinds: Vec<(String, usize)>,
    /// Trace time span `[first t, last t + dur]` in clock seconds.
    pub t_min: f64,
    pub t_max: f64,
    pub workers: Vec<WorkerBreakdown>,
    pub members: Vec<MemberBreakdown>,
    pub slowest: Vec<SlowCell>,
}

/// Fold raw events into a [`TraceSummary`] keeping the `top_k` slowest
/// cells. Events missing the coordinates a table needs are skipped for
/// that table only — a partial trace still summarizes.
pub fn summarize(events: &[Event], top_k: usize) -> TraceSummary {
    let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
    let mut workers: BTreeMap<usize, WorkerBreakdown> = BTreeMap::new();
    let mut members: BTreeMap<usize, MemberBreakdown> = BTreeMap::new();
    let mut cells: BTreeMap<(usize, usize), (f64, Option<usize>)> =
        BTreeMap::new();
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for ev in events {
        *kinds.entry(ev.kind.clone()).or_insert(0) += 1;
        t_min = t_min.min(ev.t);
        t_max = t_max.max(ev.t + ev.dur.unwrap_or(0.0));
        let dur = ev.dur.unwrap_or(0.0);
        if let Some(w) = ev.worker {
            let wb = workers.entry(w).or_insert_with(|| WorkerBreakdown {
                worker: w,
                ..WorkerBreakdown::default()
            });
            match ev.kind.as_str() {
                "claim" => wb.queue_wait += dur,
                "compile" => wb.compile += dur,
                "exec" => {
                    wb.exec += dur;
                    wb.cells += 1;
                }
                "record" => wb.record += dur,
                _ => {}
            }
        }
        if let Some(m) = ev.member {
            if ev.kind == "compile" || ev.kind == "exec" {
                let mb =
                    members.entry(m).or_insert_with(|| MemberBreakdown {
                        member: m,
                        ..MemberBreakdown::default()
                    });
                if ev.kind == "compile" {
                    mb.compile += dur;
                } else {
                    mb.exec += dur;
                    mb.cells += 1;
                    if mb.label.is_empty() {
                        let name = ev.tag_as_str("name");
                        let model = ev.tag_as_str("model");
                        mb.label = if name.is_empty() {
                            model.to_string()
                        } else if model.is_empty() {
                            name.to_string()
                        } else {
                            format!("{name}:{model}")
                        };
                    }
                }
                if let Some(c) = ev.cell {
                    let slot = cells.entry((m, c)).or_insert((0.0, None));
                    slot.0 += dur;
                    slot.1 = slot.1.or(ev.worker);
                }
            }
        }
    }
    if events.is_empty() {
        t_min = 0.0;
        t_max = 0.0;
    }
    let mut slowest: Vec<SlowCell> = cells
        .into_iter()
        .map(|((m, c), (secs, w))| SlowCell {
            member: m,
            cell: c,
            worker: w,
            seconds: secs,
        })
        .collect();
    slowest.sort_by(|a, b| {
        b.seconds
            .partial_cmp(&a.seconds)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.member.cmp(&b.member))
            .then(a.cell.cmp(&b.cell))
    });
    slowest.truncate(top_k);
    TraceSummary {
        events: events.len(),
        kinds: kinds.into_iter().collect(),
        t_min,
        t_max,
        workers: workers.into_values().collect(),
        members: members.into_values().collect(),
        slowest,
    }
}

impl TraceSummary {
    pub fn to_json(&self) -> Json {
        let kinds = Json::Obj(
            self.kinds
                .iter()
                .map(|(k, n)| (k.clone(), json::num(*n as f64)))
                .collect(),
        );
        let workers = Json::Arr(
            self.workers
                .iter()
                .map(|w| {
                    json::obj(vec![
                        ("worker", json::num(w.worker as f64)),
                        ("cells", json::num(w.cells as f64)),
                        ("queue_wait_seconds", json::num(w.queue_wait)),
                        ("compile_seconds", json::num(w.compile)),
                        ("exec_seconds", json::num(w.exec)),
                        ("record_seconds", json::num(w.record)),
                        ("total_seconds", json::num(w.total())),
                    ])
                })
                .collect(),
        );
        let members = Json::Arr(
            self.members
                .iter()
                .map(|m| {
                    json::obj(vec![
                        ("member", json::num(m.member as f64)),
                        ("label", json::s(&m.label)),
                        ("cells", json::num(m.cells as f64)),
                        ("compile_seconds", json::num(m.compile)),
                        ("exec_seconds", json::num(m.exec)),
                    ])
                })
                .collect(),
        );
        let slowest = Json::Arr(
            self.slowest
                .iter()
                .map(|c| {
                    json::obj(vec![
                        ("member", json::num(c.member as f64)),
                        ("cell", json::num(c.cell as f64)),
                        (
                            "worker",
                            c.worker
                                .map_or(Json::Null, |w| json::num(w as f64)),
                        ),
                        ("seconds", json::num(c.seconds)),
                    ])
                })
                .collect(),
        );
        json::obj(vec![
            ("events", json::num(self.events as f64)),
            ("kinds", kinds),
            ("t_min", json::num(self.t_min)),
            ("t_max", json::num(self.t_max)),
            ("workers", workers),
            ("members", members),
            ("slowest_cells", slowest),
        ])
    }

    /// Human-readable report (the default `cpt trace` output).
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let span = (self.t_max - self.t_min).max(0.0);
        let _ = writeln!(
            out,
            "trace: {} events over {:.3}s ({} workers, {} members)",
            self.events,
            span,
            self.workers.len(),
            self.members.len()
        );
        if !self.kinds.is_empty() {
            let kinds: Vec<String> = self
                .kinds
                .iter()
                .map(|(k, n)| format!("{k}={n}"))
                .collect();
            let _ = writeln!(out, "kinds: {}", kinds.join(" "));
        }
        for w in &self.workers {
            let _ = writeln!(
                out,
                "worker {}: cells={} queue-wait={:.3}s compile={:.3}s \
                 exec={:.3}s record={:.3}s total={:.3}s",
                w.worker,
                w.cells,
                w.queue_wait,
                w.compile,
                w.exec,
                w.record,
                w.total()
            );
        }
        for m in &self.members {
            let label = if m.label.is_empty() {
                String::new()
            } else {
                format!(" ({})", m.label)
            };
            let _ = writeln!(
                out,
                "member {}{label}: cells={} compile={:.3}s exec={:.3}s",
                m.member, m.cells, m.compile, m.exec
            );
        }
        if !self.slowest.is_empty() {
            let _ = writeln!(out, "slowest cells:");
            for (i, c) in self.slowest.iter().enumerate() {
                let who = c
                    .worker
                    .map_or("?".to_string(), |w| w.to_string());
                let _ = writeln!(
                    out,
                    "  {}. member {} cell {} worker {who}: {:.3}s",
                    i + 1,
                    c.member,
                    c.cell,
                    c.seconds
                );
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell_events(
        t0: f64,
        w: usize,
        m: usize,
        c: usize,
        wait: f64,
        compile: f64,
        exec: f64,
    ) -> Vec<Event> {
        let mut evs = vec![Event::new(t0, "claim").worker(w).dur(wait)];
        let mut t = t0 + wait;
        if compile > 0.0 {
            evs.push(
                Event::new(t, "compile")
                    .worker(w)
                    .member(m)
                    .cell(c)
                    .dur(compile)
                    .tag_str("outcome", "miss"),
            );
            t += compile;
        }
        evs.push(
            Event::new(t, "exec")
                .worker(w)
                .member(m)
                .cell(c)
                .dur(exec)
                .tag_str("name", "a")
                .tag_str("model", "mlp"),
        );
        evs
    }

    #[test]
    fn breakdown_sums_match_fabricated_wall() {
        let mut evs = Vec::new();
        evs.extend(cell_events(0.0, 0, 0, 0, 0.1, 1.0, 2.0));
        evs.extend(cell_events(3.1, 0, 0, 1, 0.2, 0.0, 2.0));
        evs.extend(cell_events(0.0, 1, 0, 2, 0.5, 1.5, 1.0));
        let s = summarize(&evs, 2);
        assert_eq!(s.workers.len(), 2);
        let w0 = &s.workers[0];
        assert_eq!(w0.cells, 2);
        assert!((w0.total() - (0.1 + 1.0 + 2.0 + 0.2 + 2.0)).abs() < 1e-9);
        let w1 = &s.workers[1];
        assert!((w1.total() - 3.0).abs() < 1e-9);
        assert_eq!(s.members[0].label, "a:mlp");
        assert_eq!(s.slowest.len(), 2);
        assert_eq!(s.slowest[0].cell, 0, "{:?}", s.slowest);
        assert!((s.slowest[0].seconds - 3.0).abs() < 1e-9);
        let text = s.render_text();
        assert!(text.contains("worker 0"), "{text}");
        assert!(text.contains("compile="), "{text}");
    }

    #[test]
    fn empty_trace_summarizes_cleanly() {
        let s = summarize(&[], 5);
        assert_eq!(s.events, 0);
        assert_eq!(s.t_min, 0.0);
        assert_eq!(s.t_max, 0.0);
        assert!(s.render_text().contains("0 events"));
    }
}

//! Observability: leveled logging, a metrics registry, and structured
//! span tracing — dependency-free, off the hot path by default.
//!
//! Three cooperating pieces (rust/DESIGN-obs.md has the full contract):
//!
//! * [`log`] — a leveled stderr logger behind the strict `CPT_LOG` knob
//!   (`error|warn|info|debug`, default `info`), used via the crate-root
//!   `log_error!`/`log_warn!`/`log_info!`/`log_debug!` macros.
//! * [`metrics`] — named counters/gauges/histograms with deterministic
//!   JSON snapshots; a process [`metrics::global`] registry for the
//!   coordinator plus per-instance registries for daemons.
//! * [`trace`] + [`analyze`] — a span/event tracer writing durable
//!   JSONL under `<root>/trace/` (installed by `--trace`, inert
//!   otherwise) and the folding logic behind `cpt trace DIR`.

pub mod analyze;
pub mod log;
pub mod metrics;
pub mod trace;

pub use log::Level;
pub use metrics::Registry;
pub use trace::{Event, Tracer};

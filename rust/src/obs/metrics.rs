//! Process-wide metrics registry: named counters, gauges, and
//! histograms with deterministic JSON snapshots.
//!
//! Hot paths pay one atomic op per update: counters hand out
//! `Arc<AtomicU64>` handles so a worker loop increments without
//! touching the registry lock, and the by-name convenience methods
//! (`inc`, `observe`, `set_gauge`) take the registry's map lock only to
//! find-or-create the slot. Snapshots iterate the `BTreeMap`s, so two
//! snapshots of the same state serialize byte-identically — the
//! property the `stats` wire verb and the tests lean on.
//!
//! The process-global registry ([`global`]) backs the coordinator and
//! lease instrumentation; the serve daemon holds its *own* `Registry`
//! instance so concurrent daemons in one test process don't bleed
//! counts into each other.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
struct HistData {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// One histogram's snapshot: count/sum/min/max (no buckets — the
/// analyzer derives distributions from the trace, not from here).
/// An empty histogram reports `min = max = 0.0` so snapshots stay
/// deterministic and JSON-safe (no NaN/Inf).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// Deterministic point-in-time view of a [`Registry`]: every vector is
/// sorted by metric name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

impl Snapshot {
    /// Counter value by exact name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map_or(0, |(_, v)| *v)
    }

    /// All counters under `prefix.`, with the prefix stripped — how the
    /// daemon turns `serve.errors.bad_frame = 3` into an errors table.
    pub fn counters_with_prefix(&self, prefix: &str) -> Vec<(String, u64)> {
        let full = format!("{prefix}.");
        self.counters
            .iter()
            .filter(|(n, _)| n.starts_with(&full))
            .map(|(n, v)| (n[full.len()..].to_string(), *v))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(n, v)| (n.clone(), json::num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(n, v)| (n.clone(), json::num(*v)))
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(n, h)| {
                    (
                        n.clone(),
                        json::obj(vec![
                            ("count", json::num(h.count as f64)),
                            ("sum", json::num(h.sum)),
                            ("min", json::num(h.min)),
                            ("max", json::num(h.max)),
                        ]),
                    )
                })
                .collect(),
        );
        json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }
}

/// Named counters/gauges/histograms. Cheap to update, deterministic to
/// snapshot; see the module docs for the locking story.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    /// Gauges store f64 bits in an AtomicU64.
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<Mutex<HistData>>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Find-or-create a counter and return its handle; increments on the
    /// handle are lock-free, so hot loops resolve the name once.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// One-shot increment by name (locks the map to resolve the slot).
    pub fn inc(&self, name: &str, by: u64) {
        self.counter(name).fetch_add(by, Ordering::Relaxed);
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        let slot = {
            let mut m = self.gauges.lock().unwrap();
            m.entry(name.to_string()).or_default().clone()
        };
        slot.store(value.to_bits(), Ordering::Relaxed);
    }

    /// Record one observation into a histogram (latencies, sizes).
    pub fn observe(&self, name: &str, value: f64) {
        let slot = {
            let mut m = self.hists.lock().unwrap();
            m.entry(name.to_string()).or_default().clone()
        };
        let mut h = slot.lock().unwrap();
        if h.count == 0 {
            h.min = value;
            h.max = value;
        } else {
            h.min = h.min.min(value);
            h.max = h.max.max(value);
        }
        h.count += 1;
        h.sum += value;
    }

    /// Sorted, deterministic view of everything recorded so far.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(n, v)| (n.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(n, v)| {
                (n.clone(), f64::from_bits(v.load(Ordering::Relaxed)))
            })
            .collect();
        let hists = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(n, h)| {
                let h = *h.lock().unwrap();
                (
                    n.clone(),
                    HistSnapshot {
                        count: h.count,
                        sum: h.sum,
                        min: h.min,
                        max: h.max,
                    },
                )
            })
            .collect();
        Snapshot { counters, gauges, hists }
    }
}

/// The process-wide registry used by coordinator/lease/pool
/// instrumentation. Daemons construct their own [`Registry`] instead so
/// per-daemon stats stay isolated.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot_sorted() {
        let r = Registry::new();
        r.inc("b.two", 2);
        r.inc("a.one", 1);
        let h = r.counter("b.two");
        h.fetch_add(3, Ordering::Relaxed);
        let snap = r.snapshot();
        assert_eq!(
            snap.counters,
            vec![("a.one".to_string(), 1), ("b.two".to_string(), 5)]
        );
        assert_eq!(snap.counter("b.two"), 5);
        assert_eq!(snap.counter("missing"), 0);
    }

    #[test]
    fn histograms_track_count_sum_min_max() {
        let r = Registry::new();
        r.observe("lat", 2.0);
        r.observe("lat", 0.5);
        r.observe("lat", 1.0);
        let snap = r.snapshot();
        let (_, h) = &snap.hists[0];
        assert_eq!(h.count, 3);
        assert!((h.sum - 3.5).abs() < 1e-12);
        assert_eq!(h.min, 0.5);
        assert_eq!(h.max, 2.0);
        assert!((h.mean() - 3.5 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_json_is_deterministic() {
        let r = Registry::new();
        r.inc("z", 1);
        r.inc("a", 2);
        r.set_gauge("g", 0.25);
        r.observe("h", 1.5);
        let a = r.snapshot().to_json().to_string_compact();
        let b = r.snapshot().to_json().to_string_compact();
        assert_eq!(a, b);
        assert!(a.contains("\"counters\""), "{a}");
        let parsed = Json::parse(&a).unwrap();
        assert_eq!(
            parsed
                .get("counters")
                .unwrap()
                .get("a")
                .unwrap()
                .as_usize()
                .unwrap(),
            2
        );
    }

    #[test]
    fn prefix_extraction_strips_the_prefix() {
        let r = Registry::new();
        r.inc("serve.errors.bad_frame", 3);
        r.inc("serve.errors.unknown_verb", 1);
        r.inc("serve.requests", 9);
        let snap = r.snapshot();
        assert_eq!(
            snap.counters_with_prefix("serve.errors"),
            vec![
                ("bad_frame".to_string(), 3),
                ("unknown_verb".to_string(), 1)
            ]
        );
    }
}

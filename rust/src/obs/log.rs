//! Leveled stderr logger behind the `CPT_LOG` knob.
//!
//! Four levels — `error < warn < info < debug` — with `info` the
//! default, so existing operational output is unchanged unless the
//! operator asks otherwise: `CPT_LOG=warn` silences the per-run chatter
//! (resume notes, claim summaries), `CPT_LOG=debug` exposes claim/steal
//! detail that was previously `--verbose`-only or absent. Parsing is
//! strict via [`crate::util::env_parse`], like every other `CPT_*`
//! knob: `CPT_LOG=vrbose` aborts loudly instead of silently logging at
//! the default level.
//!
//! Messages go to stderr with no added prefix or timestamp — the
//! existing `[label] note: ...` conventions already carry provenance,
//! and keeping the bytes identical means routing a message through the
//! logger is observable only through the level gate. Use the crate-root
//! macros (`crate::log_warn!` et al.); they skip formatting entirely
//! when the level is off.

use std::str::FromStr;
use std::sync::OnceLock;

use anyhow::Result;

/// Log severity, ordered so that `Error < Warn < Info < Debug` — a
/// message is emitted when its level is `<=` the configured one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Level {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<Level, String> {
        match s.to_ascii_lowercase().as_str() {
            "error" | "err" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown log level '{other}' (expected error, warn, info, \
                 or debug)"
            )),
        }
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// Resolve `CPT_LOG` strictly and pin the process-wide level. The CLI
/// calls this first thing in `run()` so a bad value becomes a clean
/// command-line error; later calls are no-ops returning the pinned
/// level.
pub fn init_from_env() -> Result<Level> {
    let lvl = crate::util::env_parse::<Level>("CPT_LOG")?.unwrap_or(Level::Info);
    Ok(*LEVEL.get_or_init(|| lvl))
}

/// The active level. Library contexts (tests, embedders) that never ran
/// [`init_from_env`] resolve lazily here; an unparsable `CPT_LOG` still
/// fails loudly — by panic, since there is no error channel — rather
/// than logging at a level the operator did not ask for.
pub fn level() -> Level {
    *LEVEL.get_or_init(|| {
        match crate::util::env_parse::<Level>("CPT_LOG") {
            Ok(l) => l.unwrap_or(Level::Info),
            Err(e) => panic!("{e:#}"),
        }
    })
}

/// Would a message at `lvl` be emitted?
pub fn enabled(lvl: Level) -> bool {
    lvl <= level()
}

/// Emit one line to stderr if `lvl` passes the gate. Callers go through
/// the `log_*!` macros, which defer formatting behind this check.
pub fn emit(lvl: Level, args: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("{args}");
    }
}

/// Log at [`Level::Error`]: failures the run cannot ignore.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Error,
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Warn`]: degraded-but-continuing conditions (retries,
/// refused writes, invalid artifacts).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Warn,
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Info`]: normal operational landmarks (run dirs,
/// resume summaries, job lifecycle).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Info,
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Debug`]: per-claim / per-steal detail, hidden by
/// default.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Debug,
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert_eq!("WARN".parse::<Level>().unwrap(), Level::Warn);
        assert_eq!("warning".parse::<Level>().unwrap(), Level::Warn);
        assert_eq!("debug".parse::<Level>().unwrap(), Level::Debug);
        assert_eq!("err".parse::<Level>().unwrap(), Level::Error);
        let e = "loud".parse::<Level>().unwrap_err();
        assert!(e.contains("unknown log level"), "{e}");
    }

    #[test]
    fn display_round_trips() {
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(lvl.as_str().parse::<Level>().unwrap(), lvl);
        }
    }
}

//! CPT schedules — the paper's core contribution (§3).
//!
//! A schedule maps a training iteration t ∈ [0, T) to a precision
//! q_t = round(S(t)) ∈ [q_min, q_max]. Schedules are built by the paper's
//! three-step decomposition:
//!
//!   1. choose a *profile* (cosine / linear / exponential / REX),
//!   2. choose the number of *cycles* n,
//!   3. choose *repeated* or *triangular* cycles (and, for asymmetric
//!      profiles, whether the triangular reflection is vertical or
//!      horizontal).
//!
//! Repeated cycles restart at q_min each cycle and grow to q_max.
//! Triangular cycles alternate direction — (down, up) pairs — so adjacent
//! cycles vary precision in opposite directions and the final (up) cycle
//! ends at q_max, per the paper's convergence constraint. The down cycle
//! is the profile's reflection:
//!   vertical   v(u) = 1 - f(u)      (mirror precision axis)
//!   horizontal v(u) = f(1 - u)      (mirror time axis)
//! For symmetric profiles these coincide (paper footnote 2) — so the suite
//! has 10 distinct members, not 12.
//!
//! Besides the CPT suite, this module provides the `Static` baseline (SBM-
//! style fixed precision), `Deficit` windows for the critical-learning-
//! period experiments (§5), and generic composition.

pub mod compose;
pub mod cost;
pub mod profiles;
pub mod suite;

pub use compose::Composed;
pub use cost::{
    mean_relative_q_of_trace, relative_cost, relative_cost_fwd_only,
    relative_cost_of_trace,
};
pub use profiles::Profile;
pub use suite::{group_of, suite_names, Group};

use anyhow::{bail, Result};

/// Reflection used for the "down" cycles of a triangular schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Reflection {
    Vertical,
    Horizontal,
}

/// Cycle arrangement (paper §3.2 step three).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Cycles {
    /// Every cycle grows q_min -> q_max.
    Repeated,
    /// (down, up) pairs; requires an even cycle count.
    Triangular(Reflection),
}

/// A fully-specified precision schedule over `total_iters` iterations.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Fixed precision (the SBM-inspired static baseline, paper §4.1).
    Static { q: f64 },
    /// Cyclic precision training.
    Cpt {
        profile: Profile,
        cycles: Cycles,
        n: usize,
        q_min: f64,
        q_max: f64,
        total_iters: usize,
    },
    /// Critical-learning-period deficit (paper §5): `q_low` inside
    /// [start, end), `q_high` outside.
    Deficit {
        q_low: f64,
        q_high: f64,
        start: usize,
        end: usize,
    },
    /// §5 remedy: hold `q_warm` for the first `steps` iterations (the
    /// critical period), then run the inner schedule shifted — "simply
    /// delaying the use of low precision until later during training".
    WithWarmup {
        q_warm: f64,
        steps: usize,
        inner: Box<Schedule>,
    },
}

impl Schedule {
    /// Build a CPT schedule, validating the paper's constraints.
    pub fn cpt(
        profile: Profile,
        cycles: Cycles,
        n: usize,
        q_min: f64,
        q_max: f64,
        total_iters: usize,
    ) -> Result<Schedule> {
        if q_min > q_max {
            bail!("q_min {q_min} > q_max {q_max}");
        }
        if n == 0 {
            bail!("cycle count must be >= 1");
        }
        if matches!(cycles, Cycles::Triangular(_)) && n % 2 != 0 {
            bail!("triangular schedules need an even cycle count (got {n})");
        }
        if total_iters == 0 {
            bail!("total_iters must be >= 1");
        }
        Ok(Schedule::Cpt { profile, cycles, n, q_min, q_max, total_iters })
    }

    pub fn static_q(q: f64) -> Schedule {
        Schedule::Static { q }
    }

    pub fn deficit(q_low: f64, q_high: f64, start: usize, end: usize) -> Schedule {
        Schedule::Deficit { q_low, q_high, start, end }
    }

    pub fn with_warmup(q_warm: f64, steps: usize, inner: Schedule) -> Schedule {
        Schedule::WithWarmup { q_warm, steps, inner: Box::new(inner) }
    }

    /// The continuous schedule value S(t) (before integer rounding).
    pub fn value_at(&self, t: usize) -> f64 {
        match *self {
            Schedule::WithWarmup { q_warm, steps, ref inner } => {
                if t < steps {
                    q_warm
                } else {
                    inner.value_at(t - steps)
                }
            }
            Schedule::Static { q } => q,
            Schedule::Deficit { q_low, q_high, start, end } => {
                if t >= start && t < end {
                    q_low
                } else {
                    q_high
                }
            }
            Schedule::Cpt { profile, cycles, n, q_min, q_max, total_iters } => {
                let t = t.min(total_iters - 1);
                // Position within the cycle structure. Guard the final
                // iteration to land exactly on u = 1 of the last cycle.
                let cycle_len = total_iters as f64 / n as f64;
                let mut cycle = ((t as f64) / cycle_len).floor() as usize;
                if cycle >= n {
                    cycle = n - 1;
                }
                let u0 = (t as f64 - cycle as f64 * cycle_len)
                    / (cycle_len - 1.0).max(1.0);
                let u = u0.clamp(0.0, 1.0);
                let v = match cycles {
                    Cycles::Repeated => profile.eval(u),
                    Cycles::Triangular(refl) => {
                        // (down, up) pairs: even-indexed cycles descend,
                        // odd-indexed ascend; the last cycle (n even) is
                        // an ascent ending at q_max.
                        if cycle % 2 == 0 {
                            match refl {
                                Reflection::Vertical => 1.0 - profile.eval(u),
                                Reflection::Horizontal => profile.eval(1.0 - u),
                            }
                        } else {
                            profile.eval(u)
                        }
                    }
                };
                q_min + (q_max - q_min) * v
            }
        }
    }

    /// The integer precision actually used at iteration t:
    /// q_t = round(S(t)) (paper §3.1).
    pub fn q_at(&self, t: usize) -> u32 {
        self.value_at(t).round().max(1.0) as u32
    }

    /// Materialize q_t for a span of iterations (what the trainer feeds
    /// the train-chunk executable as the q_fwd vector).
    pub fn q_vec(&self, start: usize, len: usize) -> Vec<f32> {
        (start..start + len).map(|t| self.q_at(t) as f32).collect()
    }

    /// Bounds (q_min, q_max) this schedule moves within.
    pub fn bounds(&self) -> (f64, f64) {
        match *self {
            Schedule::WithWarmup { q_warm, ref inner, .. } => {
                let (lo, hi) = inner.bounds();
                (lo.min(q_warm), hi.max(q_warm))
            }
            Schedule::Static { q } => (q, q),
            Schedule::Deficit { q_low, q_high, .. } => (q_low, q_high),
            Schedule::Cpt { q_min, q_max, .. } => (q_min, q_max),
        }
    }

    /// Mean of S(t)/q_max over the run — the headline compute-savings
    /// knob. For CPT this is governed by the profile mean.
    pub fn mean_relative_precision(&self, total_iters: usize) -> f64 {
        let (_, q_max) = self.bounds();
        if q_max <= 0.0 {
            return 1.0;
        }
        let s: f64 = (0..total_iters).map(|t| self.q_at(t) as f64).sum();
        s / (total_iters as f64 * q_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::suite;
    use crate::util::propcheck::propcheck;
    use crate::{prop_assert, prop_assert_close};

    fn any_cycles(r: &mut crate::util::prng::Pcg32) -> Cycles {
        match r.below(3) {
            0 => Cycles::Repeated,
            1 => Cycles::Triangular(Reflection::Vertical),
            _ => Cycles::Triangular(Reflection::Horizontal),
        }
    }

    #[test]
    fn q_within_bounds_and_integer() {
        propcheck(300, |rng| {
            let profile = Profile::all()[rng.below(4) as usize];
            let cycles = any_cycles(rng);
            let n = 2 * (1 + rng.below(6) as usize);
            let q_min = 2.0 + rng.below(4) as f64;
            let q_max = q_min + rng.below(8) as f64;
            let total = 10 + rng.below(2000) as usize;
            let s = Schedule::cpt(profile, cycles, n, q_min, q_max, total)
                .map_err(|e| e.to_string())?;
            for t in 0..total {
                let v = s.value_at(t);
                prop_assert!(
                    v >= q_min - 1e-9 && v <= q_max + 1e-9,
                    "S({t})={v} outside [{q_min},{q_max}]"
                );
                let q = s.q_at(t) as f64;
                prop_assert!(
                    q >= (q_min - 0.5).floor() && q <= (q_max + 0.5).ceil(),
                    "q({t})={q}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ends_at_q_max() {
        propcheck(200, |rng| {
            let profile = Profile::all()[rng.below(4) as usize];
            let cycles = any_cycles(rng);
            let n = 2 * (1 + rng.below(4) as usize);
            let total = n * (20 + rng.below(200) as usize);
            let s = Schedule::cpt(profile, cycles, n, 3.0, 8.0, total)
                .map_err(|e| e.to_string())?;
            prop_assert_close!(s.value_at(total - 1), 8.0, 1e-6);
            Ok(())
        });
    }

    #[test]
    fn repeated_restarts_each_cycle_at_q_min() {
        let total = 800;
        for profile in Profile::all() {
            let s = Schedule::cpt(profile, Cycles::Repeated, 8, 3.0, 8.0, total)
                .unwrap();
            for c in 0..8 {
                let t0 = c * 100;
                assert!(
                    (s.value_at(t0) - 3.0).abs() < 0.3,
                    "{profile}: cycle {c} starts at {}",
                    s.value_at(t0)
                );
            }
        }
    }

    #[test]
    fn triangular_adjacent_cycles_oppose() {
        let total = 800;
        let s = Schedule::cpt(
            Profile::Linear,
            Cycles::Triangular(Reflection::Vertical),
            8, 3.0, 8.0, total,
        )
        .unwrap();
        // even cycles decrease, odd cycles increase
        for c in 0..8 {
            let a = s.value_at(c * 100 + 10);
            let b = s.value_at(c * 100 + 80);
            if c % 2 == 0 {
                assert!(a > b, "cycle {c} should descend: {a} -> {b}");
            } else {
                assert!(a < b, "cycle {c} should ascend: {a} -> {b}");
            }
        }
    }

    #[test]
    fn symmetric_profiles_reflections_coincide() {
        propcheck(100, |rng| {
            let profile = if rng.below(2) == 0 {
                Profile::Cosine
            } else {
                Profile::Linear
            };
            let total = 400;
            let sv = Schedule::cpt(
                profile, Cycles::Triangular(Reflection::Vertical),
                4, 3.0, 8.0, total,
            ).map_err(|e| e.to_string())?;
            let sh = Schedule::cpt(
                profile, Cycles::Triangular(Reflection::Horizontal),
                4, 3.0, 8.0, total,
            ).map_err(|e| e.to_string())?;
            let t = rng.below(total as u32) as usize;
            prop_assert_close!(sv.value_at(t), sh.value_at(t), 1e-9);
            Ok(())
        });
    }

    #[test]
    fn asymmetric_reflections_differ() {
        let total = 400;
        for profile in [Profile::Rex, Profile::Exponential] {
            let sv = Schedule::cpt(
                profile, Cycles::Triangular(Reflection::Vertical),
                4, 3.0, 8.0, total,
            ).unwrap();
            let sh = Schedule::cpt(
                profile, Cycles::Triangular(Reflection::Horizontal),
                4, 3.0, 8.0, total,
            ).unwrap();
            let max_diff = (0..total)
                .map(|t| (sv.value_at(t) - sh.value_at(t)).abs())
                .fold(0.0f64, f64::max);
            assert!(max_diff > 0.5, "{profile}: reflections identical");
        }
    }

    #[test]
    fn triangular_needs_even_cycles() {
        assert!(Schedule::cpt(
            Profile::Cosine,
            Cycles::Triangular(Reflection::Vertical),
            3, 3.0, 8.0, 100,
        )
        .is_err());
    }

    #[test]
    fn static_and_deficit() {
        let s = Schedule::static_q(8.0);
        assert_eq!(s.q_at(0), 8);
        assert_eq!(s.q_at(10_000), 8);

        let d = Schedule::deficit(3.0, 8.0, 100, 600);
        assert_eq!(d.q_at(0), 8);
        assert_eq!(d.q_at(99), 8);
        assert_eq!(d.q_at(100), 3);
        assert_eq!(d.q_at(599), 3);
        assert_eq!(d.q_at(600), 8);
    }

    #[test]
    fn with_warmup_holds_then_shifts() {
        let inner = suite::by_name("RR", 2.0, 8.0, 200, 8).unwrap();
        let w = Schedule::with_warmup(8.0, 50, inner.clone());
        for t in 0..50 {
            assert_eq!(w.q_at(t), 8);
        }
        for t in 50..250 {
            assert_eq!(w.q_at(t), inner.q_at(t - 50), "t={t}");
        }
        assert_eq!(w.bounds(), (2.0, 8.0));
    }

    #[test]
    fn q_vec_matches_pointwise() {
        let s = Schedule::cpt(
            Profile::Rex, Cycles::Repeated, 8, 3.0, 8.0, 1000,
        ).unwrap();
        let v = s.q_vec(100, 64);
        for (i, &q) in v.iter().enumerate() {
            assert_eq!(q, s.q_at(100 + i) as f32);
        }
    }

    #[test]
    fn mean_relative_precision_orders_profiles() {
        let total = 4000;
        let mk = |p| {
            Schedule::cpt(p, Cycles::Repeated, 8, 3.0, 8.0, total)
                .unwrap()
                .mean_relative_precision(total)
        };
        let rex = mk(Profile::Rex);
        let lin = mk(Profile::Linear);
        let exp = mk(Profile::Exponential);
        assert!(rex < lin && lin < exp, "rex={rex} lin={lin} exp={exp}");
        let st = Schedule::static_q(8.0).mean_relative_precision(total);
        assert!((st - 1.0).abs() < 1e-9);
        assert!(exp < st);
    }
}

//! Function profiles for CPT schedules (paper §3.2, step one; Fig 2
//! upper-left).
//!
//! A profile is a growth function f: [0,1] -> [0,1] with f(0)=0, f(1)=1.
//! Precision within a cycle is q(u) = q_min + (q_max - q_min) · f(u).
//! Only growth profiles are considered because training must *end* at high
//! precision to converge (paper §3.2 / CPT [5]).
//!
//! The four profiles differ in how long they dwell near q_min — i.e. how
//! much compute they save (mean of f over [0,1], lower = cheaper):
//!
//!   REX          ∫f = 2ln2 - 1 ≈ 0.386   (dwells low   → Large savings)
//!   linear       ∫f = 0.5
//!   cosine       ∫f = 0.5                (the original CPT profile)
//!   exponential  ∫f ≈ 0.75 (k = 4)       (rises fast   → Small savings)

use std::fmt;

/// Steepness of the exponential profile. Chosen so the exponential/REX
/// pair brackets the symmetric profiles from above/below, matching the
/// paper's Small/Large grouping.
pub const EXP_K: f64 = 4.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Profile {
    /// Half-cosine growth: f(u) = (1 - cos(πu)) / 2. Symmetric.
    Cosine,
    /// f(u) = u. Symmetric.
    Linear,
    /// Fast-start saturating growth: f(u) = (1 - e^{-ku}) / (1 - e^{-k}).
    Exponential,
    /// Reverse-exponential (REX, Chen et al. [14]) growth: f(u) = u/(2-u).
    /// Slow start, sharp finish.
    Rex,
}

impl Profile {
    /// Evaluate the growth profile at u ∈ [0, 1].
    pub fn eval(self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match self {
            Profile::Cosine => 0.5 * (1.0 - (std::f64::consts::PI * u).cos()),
            Profile::Linear => u,
            Profile::Exponential => {
                (1.0 - (-EXP_K * u).exp()) / (1.0 - (-EXP_K).exp())
            }
            Profile::Rex => u / (2.0 - u),
        }
    }

    /// Exact mean of f over [0,1] — the per-cycle compute-savings factor.
    pub fn mean(self) -> f64 {
        match self {
            Profile::Cosine => 0.5,
            Profile::Linear => 0.5,
            // ∫ (1-e^{-ku})/(1-e^{-k}) du = (1 - (1-e^{-k})/k) / (1-e^{-k})
            Profile::Exponential => {
                let k = EXP_K;
                let denom = 1.0 - (-k).exp();
                (1.0 - denom / k) / denom
            }
            // ∫ u/(2-u) du = 2 ln 2 - 1
            Profile::Rex => 2.0 * std::f64::consts::LN_2 - 1.0,
        }
    }

    /// Symmetric profiles satisfy f(u) + f(1-u) = 1, which makes their
    /// horizontal and vertical reflections identical (paper footnote 2).
    pub fn is_symmetric(self) -> bool {
        matches!(self, Profile::Cosine | Profile::Linear)
    }

    pub fn all() -> [Profile; 4] {
        [Profile::Cosine, Profile::Linear, Profile::Exponential, Profile::Rex]
    }

    pub fn letter(self) -> char {
        match self {
            Profile::Cosine => 'C',
            Profile::Linear => 'L',
            Profile::Exponential => 'E',
            Profile::Rex => 'R',
        }
    }
}

impl fmt::Display for Profile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let name = match self {
            Profile::Cosine => "cosine",
            Profile::Linear => "linear",
            Profile::Exponential => "exponential",
            Profile::Rex => "rex",
        };
        write!(f, "{name}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::propcheck;
    use crate::{prop_assert, prop_assert_close};

    #[test]
    fn endpoints() {
        for p in Profile::all() {
            assert!(p.eval(0.0).abs() < 1e-12, "{p}: f(0) != 0");
            assert!((p.eval(1.0) - 1.0).abs() < 1e-12, "{p}: f(1) != 1");
        }
    }

    #[test]
    fn monotone_increasing() {
        propcheck(200, |rng| {
            let p = Profile::all()[rng.below(4) as usize];
            let a = rng.next_f32() as f64;
            let b = rng.next_f32() as f64;
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            prop_assert!(
                p.eval(lo) <= p.eval(hi) + 1e-12,
                "{p} not monotone at {lo},{hi}"
            );
            Ok(())
        });
    }

    #[test]
    fn means_match_numeric_integral() {
        for p in Profile::all() {
            let n = 100_000;
            let num: f64 = (0..n)
                .map(|i| p.eval((i as f64 + 0.5) / n as f64))
                .sum::<f64>()
                / n as f64;
            assert!(
                (num - p.mean()).abs() < 1e-4,
                "{p}: numeric {num} vs analytic {}",
                p.mean()
            );
        }
    }

    #[test]
    fn symmetry_flags_correct() {
        propcheck(200, |rng| {
            let p = Profile::all()[rng.below(4) as usize];
            let u = rng.next_f32() as f64;
            let sym_holds = (p.eval(u) + p.eval(1.0 - u) - 1.0).abs() < 1e-9;
            if p.is_symmetric() {
                prop_assert!(sym_holds, "{p} claimed symmetric, broken at {u}");
            }
            Ok(())
        });
        // and the asymmetric ones really are asymmetric somewhere
        for p in [Profile::Exponential, Profile::Rex] {
            assert!((p.eval(0.25) + p.eval(0.75) - 1.0).abs() > 1e-3);
        }
    }

    #[test]
    fn savings_ordering() {
        // REX dwells lowest, exponential highest — the basis of the
        // paper's Large/Medium/Small groups.
        assert!(Profile::Rex.mean() < Profile::Linear.mean());
        assert!(Profile::Linear.mean() < Profile::Exponential.mean());
        assert!((Profile::Cosine.mean() - 0.5).abs() < 1e-12);
    }
}

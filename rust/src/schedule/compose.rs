//! Schedule composition — generalizes the paper's suite beyond its ten
//! members (paper §6 points toward richer schedules; these combinators
//! cover the variants the text discusses but does not sweep):
//!
//! * [`Composed::warmup`] — hold `q_max` for W steps, then run an inner
//!   schedule (the §5 remedy: "delaying the use of low precision until
//!   later during the training process");
//! * [`Composed::sequence`] — concatenate schedules over step spans
//!   (e.g. aggressive early, conservative late);
//! * [`Composed::clamp`] — impose a floor/ceiling on another schedule
//!   (e.g. raise the effective q_min during the critical period only);
//! * [`Composed::sampled`] — re-evaluate the inner schedule every `rate`
//!   steps (the sampling-rate knob of REX [14]; paper footnote 1 argues
//!   integer rounding makes it less pertinent — this makes that claim
//!   testable).
//!
//! All combinators preserve the `q_at = round(value_at)` contract and are
//! accepted anywhere a base [`Schedule`] is (`trainer`, benches) via
//! [`AnySchedule`].

use super::Schedule;

/// A composed precision schedule.
#[derive(Clone, Debug)]
pub enum Composed {
    Base(Schedule),
    /// q_max for `steps`, then the inner schedule (shifted).
    Warmup { q: f64, steps: usize, inner: Box<Composed> },
    /// Concatenation: each segment runs for its span of steps.
    Sequence { segments: Vec<(usize, Composed)> },
    /// Clamp the inner schedule's value into [lo, hi].
    Clamp { lo: f64, hi: f64, inner: Box<Composed> },
    /// Hold the inner schedule's value constant within windows of `rate`
    /// steps (sampling rate; REX [14]).
    Sampled { rate: usize, inner: Box<Composed> },
}

impl Composed {
    pub fn base(s: Schedule) -> Composed {
        Composed::Base(s)
    }

    pub fn warmup(q: f64, steps: usize, inner: Composed) -> Composed {
        Composed::Warmup { q, steps, inner: Box::new(inner) }
    }

    pub fn sequence(segments: Vec<(usize, Composed)>) -> Composed {
        Composed::Sequence { segments }
    }

    pub fn clamp(lo: f64, hi: f64, inner: Composed) -> Composed {
        Composed::Clamp { lo, hi, inner: Box::new(inner) }
    }

    pub fn sampled(rate: usize, inner: Composed) -> Composed {
        Composed::Sampled { rate: rate.max(1), inner: Box::new(inner) }
    }

    /// Continuous value S(t).
    pub fn value_at(&self, t: usize) -> f64 {
        match self {
            Composed::Base(s) => s.value_at(t),
            Composed::Warmup { q, steps, inner } => {
                if t < *steps {
                    *q
                } else {
                    inner.value_at(t - steps)
                }
            }
            Composed::Sequence { segments } => {
                let mut off = 0usize;
                for (span, seg) in segments {
                    if t < off + span {
                        return seg.value_at(t - off);
                    }
                    off += span;
                }
                // past the end: hold the last segment's final value
                match segments.last() {
                    Some((span, seg)) => seg.value_at(span.saturating_sub(1)),
                    None => 32.0,
                }
            }
            Composed::Clamp { lo, hi, inner } => {
                inner.value_at(t).clamp(*lo, *hi)
            }
            Composed::Sampled { rate, inner } => {
                inner.value_at(t - t % rate)
            }
        }
    }

    /// Integer precision at step t (same contract as [`Schedule::q_at`]).
    pub fn q_at(&self, t: usize) -> u32 {
        self.value_at(t).round().max(1.0) as u32
    }

    pub fn q_vec(&self, start: usize, len: usize) -> Vec<f32> {
        (start..start + len).map(|t| self.q_at(t) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{suite, Cycles, Profile};
    use crate::util::propcheck::propcheck;
    use crate::prop_assert;

    fn cr(total: usize) -> Schedule {
        suite::by_name("CR", 3.0, 8.0, total, 8).unwrap()
    }

    #[test]
    fn warmup_holds_then_delegates() {
        let c = Composed::warmup(8.0, 100, Composed::base(cr(400)));
        for t in 0..100 {
            assert_eq!(c.q_at(t), 8);
        }
        // after warmup, matches the inner schedule shifted by 100
        let inner = cr(400);
        for t in 100..500 {
            assert_eq!(c.q_at(t), inner.q_at(t - 100), "t={t}");
        }
    }

    #[test]
    fn warmup_fixes_critical_period_exposure() {
        // the §5 remedy: a warmup composed over an aggressive schedule
        // spends zero early steps below q_max
        let aggressive = suite::by_name("RR", 2.0, 8.0, 400, 8).unwrap();
        let c = Composed::warmup(8.0, 120, Composed::base(aggressive));
        let early_low = (0..120).filter(|&t| c.q_at(t) < 8).count();
        assert_eq!(early_low, 0);
    }

    #[test]
    fn sequence_concatenates_and_holds_tail() {
        let c = Composed::sequence(vec![
            (100, Composed::base(Schedule::static_q(4.0))),
            (100, Composed::base(Schedule::static_q(8.0))),
        ]);
        assert_eq!(c.q_at(0), 4);
        assert_eq!(c.q_at(99), 4);
        assert_eq!(c.q_at(100), 8);
        assert_eq!(c.q_at(199), 8);
        assert_eq!(c.q_at(10_000), 8); // holds final value
    }

    #[test]
    fn clamp_bounds_values() {
        propcheck(100, |rng| {
            let total = 200 + rng.below(400) as usize;
            let c = Composed::clamp(4.0, 7.0, Composed::base(cr(total)));
            for t in 0..total {
                let q = c.q_at(t);
                prop_assert!((4..=7).contains(&q), "q={q} at t={t}");
            }
            Ok(())
        });
    }

    #[test]
    fn sampled_is_piecewise_constant() {
        let c = Composed::sampled(16, Composed::base(cr(320)));
        for t in 0..320 {
            assert_eq!(c.q_at(t), c.q_at(t - t % 16), "t={t}");
        }
    }

    #[test]
    fn sampling_rate_barely_changes_integer_schedule() {
        // paper footnote 1: rounding makes the sampling rate less
        // pertinent for precision schedules. Quantify: a rate-8 sampled
        // CR differs from plain CR on a small fraction of steps.
        let total = 800;
        let plain = Composed::base(cr(total));
        let sampled = Composed::sampled(8, Composed::base(cr(total)));
        let diff = (0..total)
            .filter(|&t| plain.q_at(t) != sampled.q_at(t))
            .count();
        assert!(
            (diff as f64) < 0.25 * total as f64,
            "sampling changed {diff}/{total} steps"
        );
    }

    #[test]
    fn composition_nests() {
        let s = Schedule::cpt(
            Profile::Rex, Cycles::Repeated, 8, 2.0, 8.0, 400,
        )
        .unwrap();
        let c = Composed::warmup(
            8.0,
            50,
            Composed::clamp(3.0, 8.0, Composed::sampled(4, Composed::base(s))),
        );
        for t in 0..500 {
            let q = c.q_at(t);
            assert!((3..=8).contains(&q), "q={q} at t={t}");
        }
        assert_eq!(c.q_at(0), 8);
    }

    #[test]
    fn q_vec_matches_pointwise() {
        let c = Composed::warmup(8.0, 10, Composed::base(cr(100)));
        let v = c.q_vec(5, 20);
        for (i, &q) in v.iter().enumerate() {
            assert_eq!(q as u32, c.q_at(5 + i));
        }
    }
}

//! Analytic training-cost model for schedules.
//!
//! The exact per-run BitOps number comes from `quant::bitops` (it needs the
//! model's GEMM FLOP counts). This module provides the *relative* cost of a
//! schedule against the static-q_max baseline, which is model-independent
//! under the paper's BitOps formula:
//!
//!   fwd  cost(t) ∝ (q_t / 32)^2            (both GEMM operands at q_t)
//!   bwd  cost(t) ∝ 2 · (q_bwd/32)(q_t/32)  (cotangent at fixed q_bwd =
//!                                           q_max, residuals at q_t)
//!
//! so   relative_cost = Σ_t [q_t² + 2·q_max·q_t] / Σ_t [q_max² + 2·q_max²].

use super::Schedule;

/// Relative training cost (quantized-GEMM BitOps) of `schedule` vs a
/// static q_max baseline, forward + backward, over `total_iters`.
pub fn relative_cost(schedule: &Schedule, q_max: f64, total_iters: usize) -> f64 {
    let mut num = 0.0;
    for t in 0..total_iters {
        let q = schedule.q_at(t) as f64;
        num += q * q + 2.0 * q_max * q;
    }
    let den = total_iters as f64 * (q_max * q_max + 2.0 * q_max * q_max);
    num / den
}

/// Exact relative cost of a *realized* precision trace — the integer
/// `q_t` series a run actually executed — against the static `q_max`
/// baseline. [`relative_cost`] predicts this from a schedule; adaptive
/// policies make the trace data-dependent, so the realized figure is
/// computed from the trace itself (the trainer accumulates it via
/// [`crate::quant::BitOpsAccountant::realized_relative_cost`], which
/// agrees with this function exactly — the model's FLOP factor cancels).
pub fn relative_cost_of_trace(qs: &[u32], q_max: f64) -> f64 {
    if qs.is_empty() || q_max <= 0.0 {
        return 1.0;
    }
    let mut num = 0.0;
    for &q in qs {
        let q = q as f64;
        num += q * q + 2.0 * q_max * q;
    }
    num / (qs.len() as f64 * 3.0 * q_max * q_max)
}

/// Realized mean `q_t / q_max` of a trace — the headline compute-savings
/// figure for a data-dependent run (the trace counterpart of
/// [`Schedule::mean_relative_precision`]).
pub fn mean_relative_q_of_trace(qs: &[u32], q_max: f64) -> f64 {
    if qs.is_empty() || q_max <= 0.0 {
        return 1.0;
    }
    let s: f64 = qs.iter().map(|&q| q as f64).sum();
    s / (qs.len() as f64 * q_max)
}

/// Forward-pass-only relative cost (used for inference-cost style
/// comparisons and ablation reporting).
pub fn relative_cost_fwd_only(
    schedule: &Schedule,
    q_max: f64,
    total_iters: usize,
) -> f64 {
    let mut num = 0.0;
    for t in 0..total_iters {
        let q = schedule.q_at(t) as f64;
        num += q * q;
    }
    num / (total_iters as f64 * q_max * q_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::suite::{by_name, group_of, suite_names, Group};

    #[test]
    fn static_baseline_costs_one() {
        let s = Schedule::static_q(8.0);
        assert!((relative_cost(&s, 8.0, 1000) - 1.0).abs() < 1e-12);
        assert!((relative_cost_fwd_only(&s, 8.0, 1000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn every_suite_schedule_saves_compute() {
        for name in suite_names() {
            let s = by_name(name, 3.0, 8.0, 4000, 8).unwrap();
            let c = relative_cost(&s, 8.0, 4000);
            assert!(c < 1.0, "{name}: relative cost {c} >= 1");
            assert!(c > 0.2, "{name}: implausibly low cost {c}");
        }
    }

    #[test]
    fn groups_order_cost() {
        let total = 8000;
        let cost = |n: &str| {
            relative_cost(&by_name(n, 3.0, 8.0, total, 8).unwrap(), 8.0, total)
        };
        let avg = |g: Group| {
            let names: Vec<_> = suite_names()
                .into_iter()
                .filter(|n| group_of(n) == g)
                .collect();
            names.iter().map(|n| cost(n)).sum::<f64>() / names.len() as f64
        };
        let (l, m, s) = (avg(Group::Large), avg(Group::Medium), avg(Group::Small));
        assert!(l < m && m < s, "cost groups broken: {l:.3} {m:.3} {s:.3}");
    }

    #[test]
    fn trace_cost_agrees_with_schedule_prediction() {
        // materializing a schedule into its integer trace and costing the
        // trace must reproduce the analytic figure exactly (same formula,
        // same rounding)
        let total = 2000;
        for name in suite_names() {
            let s = by_name(name, 3.0, 8.0, total, 8).unwrap();
            let qs: Vec<u32> = (0..total).map(|t| s.q_at(t)).collect();
            let from_trace = relative_cost_of_trace(&qs, 8.0);
            let from_schedule = relative_cost(&s, 8.0, total);
            assert!(
                (from_trace - from_schedule).abs() < 1e-12,
                "{name}: {from_trace} vs {from_schedule}"
            );
            let mq = mean_relative_q_of_trace(&qs, 8.0);
            let want = s.mean_relative_precision(total);
            assert!((mq - want).abs() < 1e-12, "{name}: {mq} vs {want}");
        }
    }

    #[test]
    fn trace_cost_degenerate_inputs() {
        assert_eq!(relative_cost_of_trace(&[], 8.0), 1.0);
        assert_eq!(mean_relative_q_of_trace(&[], 8.0), 1.0);
        assert_eq!(relative_cost_of_trace(&[8; 10], 0.0), 1.0);
        // a static-q_max trace costs exactly 1
        assert!((relative_cost_of_trace(&[8; 64], 8.0) - 1.0).abs() < 1e-12);
        assert!((mean_relative_q_of_trace(&[8; 64], 8.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deficit_cost_between_bounds() {
        // a window at q_min must cost less than static q_max, more than
        // static q_min
        let d = Schedule::deficit(3.0, 8.0, 0, 500);
        let c = relative_cost(&d, 8.0, 1000);
        let lo = relative_cost(&Schedule::static_q(3.0), 8.0, 1000);
        assert!(c < 1.0 && c > lo, "c={c} lo={lo}");
    }
}

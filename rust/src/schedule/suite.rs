//! The paper's named suite of ten CPT schedules and their savings groups
//! (§3.2):
//!
//!   Group I   (Large savings):  RR, RTH
//!   Group II  (Medium savings): LR, LT, CR, CT, RTV, ETV
//!   Group III (Small savings):  ER, ETH
//!
//! Naming: first letter = profile (C osine, L inear, E xponential, R EX);
//! suffix R = repeated, T = triangular (TV/TH = vertical/horizontal
//! reflection for the asymmetric profiles). CR is the original CPT
//! schedule of Fu et al. [5].

use anyhow::{bail, Result};

use super::{Cycles, Profile, Reflection, Schedule};

/// Savings group (paper §3.2). Ordered by training-cost reduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Group {
    /// Group I — largest compute savings (most aggressive quantization).
    Large,
    /// Group II — medium savings.
    Medium,
    /// Group III — smallest savings (most conservative quantization).
    Small,
    /// Not part of the CPT suite (static baseline, deficit schedules).
    None,
}

impl Group {
    pub fn label(self) -> &'static str {
        match self {
            Group::Large => "I/Large",
            Group::Medium => "II/Medium",
            Group::Small => "III/Small",
            Group::None => "-",
        }
    }
}

/// All ten suite names, in the paper's group order.
pub fn suite_names() -> [&'static str; 10] {
    ["RR", "RTH", "LR", "LT", "CR", "CT", "RTV", "ETV", "ER", "ETH"]
}

/// The savings group of a named schedule.
pub fn group_of(name: &str) -> Group {
    match name {
        "RR" | "RTH" => Group::Large,
        "LR" | "LT" | "CR" | "CT" | "RTV" | "ETV" => Group::Medium,
        "ER" | "ETH" => Group::Small,
        _ => Group::None,
    }
}

/// Construct a named suite schedule.
///
/// `n` is the cycle count (paper default: 8 for full training runs, 2 for
/// short fine-tuning); `total_iters` the training length in optimizer
/// steps.
pub fn by_name(
    name: &str,
    q_min: f64,
    q_max: f64,
    total_iters: usize,
    n: usize,
) -> Result<Schedule> {
    let (profile, cycles) = decode(name)?;
    Schedule::cpt(profile, cycles, n, q_min, q_max, total_iters)
}

fn decode(name: &str) -> Result<(Profile, Cycles)> {
    let profile = match name.chars().next() {
        Some('C') => Profile::Cosine,
        Some('L') => Profile::Linear,
        Some('E') => Profile::Exponential,
        Some('R') => Profile::Rex,
        _ => bail!("unknown schedule '{name}'"),
    };
    let cycles = match &name[1..] {
        "R" => Cycles::Repeated,
        // Symmetric profiles: one triangular variant ("T").
        "T" if profile.is_symmetric() => {
            Cycles::Triangular(Reflection::Vertical)
        }
        "TV" if !profile.is_symmetric() => {
            Cycles::Triangular(Reflection::Vertical)
        }
        "TH" if !profile.is_symmetric() => {
            Cycles::Triangular(Reflection::Horizontal)
        }
        suffix => bail!("unknown schedule suffix '{suffix}' in '{name}'"),
    };
    Ok((profile, cycles))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_construct() {
        for name in suite_names() {
            let s = by_name(name, 3.0, 8.0, 1000, 8)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(s.q_at(999) >= 7, "{name} must end near q_max");
        }
    }

    #[test]
    fn group_assignment_complete() {
        for name in suite_names() {
            assert_ne!(group_of(name), Group::None, "{name} ungrouped");
        }
        assert_eq!(group_of("STATIC"), Group::None);
    }

    #[test]
    fn cr_is_original_cpt() {
        // The original CPT schedule: cosine profile, repeated cycles,
        // rising q_min -> q_max within each cycle.
        let s = by_name("CR", 3.0, 8.0, 800, 8).unwrap();
        assert!((s.value_at(0) - 3.0).abs() < 0.1);
        assert!((s.value_at(99) - 8.0).abs() < 0.3);
        assert!((s.value_at(100) - 3.0).abs() < 0.3); // restart
    }

    #[test]
    fn groups_order_mean_precision() {
        // Empirical check of the paper's grouping: mean relative precision
        // must order Large < Medium < Small.
        let total = 8000;
        let mean = |name: &str| {
            by_name(name, 3.0, 8.0, total, 8)
                .unwrap()
                .mean_relative_precision(total)
        };
        let large: f64 =
            ["RR", "RTH"].iter().map(|n| mean(n)).sum::<f64>() / 2.0;
        let medium: f64 = ["LR", "LT", "CR", "CT", "RTV", "ETV"]
            .iter()
            .map(|n| mean(n))
            .sum::<f64>()
            / 6.0;
        let small: f64 =
            ["ER", "ETH"].iter().map(|n| mean(n)).sum::<f64>() / 2.0;
        assert!(
            large < medium && medium < small,
            "group means broken: L={large:.3} M={medium:.3} S={small:.3}"
        );
    }

    #[test]
    fn invalid_names_rejected() {
        for bad in ["XX", "C", "CTV", "RT", "cosine", ""] {
            assert!(by_name(bad, 3.0, 8.0, 100, 8).is_err(), "{bad}");
        }
    }
}

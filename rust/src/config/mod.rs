//! CLI argument parsing + TOML-subset experiment presets (clap/serde are
//! unavailable offline).

pub mod toml;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: a subcommand, positional args, and --flags.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Cli {
    /// Parse `args` (without argv[0]). Flags are `--key value` or
    /// `--key=value`; bare `--key` is a boolean true.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut it = args.into_iter().peekable();
        let mut cli = Cli::default();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                cli.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    cli.flags.insert(k.to_string(), v.to_string());
                } else {
                    // peek: next token is a value unless it's a flag
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            cli.flags.insert(key.to_string(), v);
                        }
                        _ => {
                            cli.flags.insert(key.to_string(), "true".into());
                        }
                    }
                }
            } else {
                cli.positional.push(a);
            }
        }
        Ok(cli)
    }

    pub fn from_env() -> Result<Cli> {
        Cli::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flag(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.flag(key)
            .with_context(|| format!("missing required flag --{key}"))
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.flag(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Reject unknown flags (catches typos in scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!(
                    "unknown flag --{k} (known: {})",
                    known.join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let c = cli("train --model cnn_tiny --steps 100 --verbose --qmax=6");
        assert_eq!(c.command, "train");
        assert_eq!(c.flag("model"), Some("cnn_tiny"));
        assert_eq!(c.usize_or("steps", 0).unwrap(), 100);
        assert!(c.bool("verbose"));
        assert_eq!(c.f64_or("qmax", 8.0).unwrap(), 6.0);
    }

    #[test]
    fn defaults_and_lists() {
        let c = cli("sweep --schedules CR,RR,STATIC");
        assert_eq!(c.usize_or("trials", 3).unwrap(), 3);
        assert_eq!(
            c.list_or("schedules", &[]),
            vec!["CR", "RR", "STATIC"]
        );
        assert_eq!(c.list_or("qmaxes", &["6", "8"]), vec!["6", "8"]);
    }

    #[test]
    fn unknown_flags_rejected() {
        let c = cli("train --modle x");
        assert!(c.check_known(&["model"]).is_err());
        let c2 = cli("train --model x");
        assert!(c2.check_known(&["model"]).is_ok());
    }

    #[test]
    fn require_missing() {
        let c = cli("train");
        assert!(c.require("model").is_err());
    }
}

//! TOML-subset parser for experiment preset files (configs/*.toml).
//!
//! Supported grammar (sufficient for flat experiment presets and
//! campaign manifests):
//!   [section]
//!   [[table.array]]
//!   key = "string" | 123 | 1.5 | true | false | [v, v, ...]
//!   # comments
//!
//! Plain `[section]` values land in a `BTreeMap<section, Section>`;
//! the root (pre-section) keys go under section "". Each `[[name]]`
//! header appends a fresh table to `tables[name]` (in file order) and
//! routes subsequent keys into it — the shape `cpt campaign` uses for
//! its `[[campaign.sweep]]` member list.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    List(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_list(&self) -> Result<&[Value]> {
        match self {
            Value::List(v) => Ok(v),
            _ => bail!("not a list: {self:?}"),
        }
    }
}

pub type Section = BTreeMap<String, Value>;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, Section>,
    /// `[[name]]` table arrays, in file order per name.
    pub tables: BTreeMap<String, Vec<Section>>,
}

/// Where the keys currently being parsed should land.
enum Target {
    Section(String),
    /// Last entry of `tables[name]`.
    Table(String),
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = Target::Section(String::new());
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                let name = name.strip_suffix("]]").with_context(|| {
                    format!("line {}: bad table-array header", lineno + 1)
                })?;
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default().push(Section::new());
                current = Target::Table(name);
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: bad section", lineno + 1))?;
                let name = name.trim().to_string();
                doc.sections.entry(name.clone()).or_default();
                current = Target::Section(name);
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            let slot = match &current {
                Target::Section(name) => {
                    doc.sections.entry(name.clone()).or_default()
                }
                // both maps were populated when the header was parsed
                Target::Table(name) => {
                    doc.tables.get_mut(name).unwrap().last_mut().unwrap()
                }
            };
            slot.insert(k.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TomlDoc> {
        let src = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {}", path.as_ref().display()))?;
        TomlDoc::parse(&src)
    }

    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// All `[[name]]` tables, in file order (empty if none appeared).
    pub fn table(&self, name: &str) -> &[Section] {
        self.tables.get(name).map(Vec::as_slice).unwrap_or(&[])
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .with_context(|| format!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .with_context(|| format!("unterminated list: {s}"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::List(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .with_context(|| format!("cannot parse value: {s}"))
}

/// Split on commas not inside quotes/brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_preset() {
        let doc = TomlDoc::parse(
            r#"
# a preset
title = "fig3"

[sweep]
model = "cnn_tiny"        # the CIFAR stand-in
schedules = ["CR", "RR"]
q_maxes = [6, 8]
trials = 3
verbose = false
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "title").unwrap().as_str().unwrap(), "fig3");
        let s = doc.section("sweep").unwrap();
        assert_eq!(s["model"].as_str().unwrap(), "cnn_tiny");
        assert_eq!(s["trials"].as_usize().unwrap(), 3);
        assert!(!s["verbose"].as_bool().unwrap());
        let scheds = s["schedules"].as_list().unwrap();
        assert_eq!(scheds.len(), 2);
        assert_eq!(scheds[0].as_str().unwrap(), "CR");
        let qs = s["q_maxes"].as_list().unwrap();
        assert_eq!(qs[1].as_f64().unwrap(), 8.0);
    }

    #[test]
    fn parses_sharded_preset_fields() {
        // the sharding/persistence keys cmd_preset reads: shard is an
        // "I/N" string, run_dir a path string, resume a bool
        let doc = TomlDoc::parse(
            r#"
title = "fig3_shard1"

[sweep]
model = "cnn_tiny"
trials = 3
shard = "1/4"
run_dir = "runs/fig3/shard1"
resume = true
"#,
        )
        .unwrap();
        let s = doc.section("sweep").unwrap();
        assert_eq!(s["shard"].as_str().unwrap(), "1/4");
        assert_eq!(s["run_dir"].as_str().unwrap(), "runs/fig3/shard1");
        assert!(s["resume"].as_bool().unwrap());
        // shard must be written as a string — a bare 1/4 is not a value
        assert!(TomlDoc::parse("[sweep]\nshard = 1/4").is_err());
    }

    #[test]
    fn parses_table_arrays_in_file_order() {
        let doc = TomlDoc::parse(
            r#"
[campaign]
name = "fig367"

[[campaign.sweep]]
name = "cifar"
model = "cnn_tiny"
q_maxes = [6, 8]

[[campaign.sweep]]
name = "ogbn"
model = "gcn_qagg"   # second member
trials = 2
"#,
        )
        .unwrap();
        assert_eq!(
            doc.get("campaign", "name").unwrap().as_str().unwrap(),
            "fig367"
        );
        let members = doc.table("campaign.sweep");
        assert_eq!(members.len(), 2);
        assert_eq!(members[0]["name"].as_str().unwrap(), "cifar");
        assert_eq!(members[0]["q_maxes"].as_list().unwrap().len(), 2);
        assert_eq!(members[1]["model"].as_str().unwrap(), "gcn_qagg");
        assert_eq!(members[1]["trials"].as_usize().unwrap(), 2);
        // a [section] after a table entry redirects keys back to it
        let doc2 = TomlDoc::parse("[[t]]\na = 1\n[s]\nb = 2").unwrap();
        assert_eq!(doc2.table("t")[0]["a"].as_usize().unwrap(), 1);
        assert_eq!(doc2.get("s", "b").unwrap().as_usize().unwrap(), 2);
        assert!(doc2.table("missing").is_empty());
    }

    #[test]
    fn table_array_header_errors() {
        assert!(TomlDoc::parse("[[unclosed").is_err());
        assert!(TomlDoc::parse("[[half]").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
    }

    #[test]
    fn empty_and_nested_lists() {
        let doc = TomlDoc::parse("a = []\nb = [[1,2],[3]]").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_list().unwrap().len(), 0);
        let b = doc.get("", "b").unwrap().as_list().unwrap();
        assert_eq!(b[0].as_list().unwrap()[1].as_f64().unwrap(), 2.0);
    }
}

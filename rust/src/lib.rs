//! # cpt — Better Schedules for Low Precision Training
//!
//! A Rust + JAX + Pallas reproduction of Wolfe & Kyrillidis, *Better
//! Schedules for Low Precision Training of Deep Neural Networks*
//! (Machine Learning, 2024).
//!
//! Three layers (see DESIGN.md):
//! * **L1** Pallas kernels (python/compile/kernels): fused
//!   quantize→matmul with runtime bit-widths;
//! * **L2** JAX models (python/compile/models): quantized-training
//!   fwd/bwd, AOT-lowered to HLO text;
//! * **L3** this crate: the precision-schedule suite, adaptive precision
//!   policies (feedback-driven q_t — see [`policy`] and
//!   rust/DESIGN-policy.md), PJRT runtime, trainer, synthetic datasets,
//!   BitOps accounting (including exact realized-trace cost figures) and
//!   the experiment coordinator, plus a long-running campaign service
//!   with spec-hash result caching (`cpt serve` — see [`server`] and
//!   rust/DESIGN-serve.md). Python never runs at training time.
//!
//! Quick start:
//! ```no_run
//! use cpt::prelude::*;
//!
//! let rt = Runtime::cpu().unwrap();
//! let manifest = Manifest::load("artifacts").unwrap();
//! let model = rt.load_model(manifest.model("mlp").unwrap()).unwrap();
//! let schedule = cpt::schedule::suite::by_name("CR", 3.0, 8.0, 128, 8).unwrap();
//! let mut data = cpt::coordinator::dataset_for("mlp", 0).unwrap();
//! let lr = LrSchedule::Constant { lr: 0.05 };
//! let mut trainer = Trainer::new(&model, data.as_mut(), schedule, lr,
//!                                TrainConfig::default());
//! let history = trainer.run().unwrap();
//! println!("final accuracy {:?}", history.final_eval_metric());
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod quant;
pub mod runtime;
pub mod schedule;
pub mod server;
pub mod trainer;
pub mod util;

/// Common imports for examples and benches.
pub mod prelude {
    pub use crate::config::Cli;
    pub use crate::coordinator::{
        aggregate, dataset_for, merge_campaign_roots, merge_run_dirs, recipe,
        run_campaign, run_one, run_one_with_policy, run_sweep,
        run_sweep_timed, sweep_cells, CampaignPlan, CampaignSpec, RunOutcome,
        RunStore, ShardId, SweepCell, SweepPlan, SweepReport, SweepSpec,
        SweepTiming,
    };
    pub use crate::data::Dataset;
    pub use crate::metrics::History;
    pub use crate::policy::{
        ChunkFeedback, PolicySpec, PrecisionPolicy, StaticPolicy,
    };
    pub use crate::quant::BitOpsAccountant;
    pub use crate::runtime::{
        HostTensor, LiteralArena, LoadedModel, Manifest, Runtime,
    };
    pub use crate::schedule::{
        group_of, suite, Cycles, Profile, Reflection, Schedule,
    };
    pub use crate::trainer::{LrSchedule, TrainConfig, Trainer};
}

/// Default artifacts directory, overridable via CPT_ARTIFACTS.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("CPT_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string())
        .into()
}

/// Default results directory, overridable via CPT_RESULTS.
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("CPT_RESULTS")
        .unwrap_or_else(|_| "results".to_string())
        .into()
}

/// Default sweep-executor worker count, overridable via CPT_JOBS (the
/// bench targets have no CLI, so the env var is their `--jobs`).
/// 1 = serial on the caller's runtime.
pub fn default_jobs() -> usize {
    std::env::var("CPT_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Bench scale knob: CPT_BENCH_SCALE=quick|full (default quick). The
/// quick scale keeps every figure reproduction minutes-long on one CPU
/// core; full uses the paper-shaped trial counts / step counts.
pub fn bench_scale() -> BenchScale {
    match std::env::var("CPT_BENCH_SCALE").as_deref() {
        Ok("full") => BenchScale::Full,
        _ => BenchScale::Quick,
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchScale {
    Quick,
    Full,
}

impl BenchScale {
    pub fn trials(self) -> usize {
        match self {
            BenchScale::Quick => 1,
            BenchScale::Full => 3,
        }
    }

    pub fn steps(self, quick: usize, full: usize) -> usize {
        match self {
            BenchScale::Quick => quick,
            BenchScale::Full => full,
        }
    }
}

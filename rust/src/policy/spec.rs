//! Canonical, hashable description of a precision policy.
//!
//! A [`PolicySpec`] is to a policy what a schedule name is to a schedule:
//! the result-determining identity that flows into the sweep-spec hash,
//! the TOML files, and the CLI. Every field of every variant changes the
//! realized `q_t` trace, so every field is inside [`PolicySpec::canonical`]
//! — the string [`crate::coordinator::SweepPlan`] hashes. The default
//! (`StaticSuite`) is deliberately *absent* from the hash stream so a
//! sweep that never mentions policies hashes exactly as it did before the
//! policy subsystem existed.
//!
//! Three surface syntaxes, one canonical form:
//! * CLI / compact TOML key: `loss_plateau:ema=0.5,patience=2` (the part
//!   after `:` is optional — omitted keys take their defaults);
//! * `[sweep.policy]` preset table: `kind = "loss_plateau"` plus one key
//!   per parameter;
//! * [`PolicySpec::canonical`]: the compact syntax with *every* parameter
//!   spelled out in sorted key order — parsing it reproduces the spec
//!   exactly (round-trip tested).

use anyhow::{bail, Context, Result};

use crate::config::toml::Section;

/// How the trainer chooses the next chunk's precision.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum PolicySpec {
    /// Legacy path: the cell's named schedule drives `q_t` (the paper's
    /// precomputed CPT suite). The default everywhere.
    #[default]
    StaticSuite,
    /// MuPPET-style switching: hold a low precision and raise it by
    /// `q_step` bits whenever the EMA of the chunk training loss stops
    /// improving for `patience` consecutive chunks (with a post-switch
    /// `cooldown` as hysteresis).
    LossPlateau {
        /// EMA smoothing factor in (0, 1]; 1 = no smoothing.
        ema: f64,
        /// Chunks without relative improvement tolerated before a switch.
        patience: usize,
        /// Relative EMA improvement that counts as progress (hysteresis
        /// band), in [0, 1).
        min_delta: f64,
        /// Bits added per switch (> 0).
        q_step: f64,
        /// Chunks ignored after a switch before plateau tracking resumes.
        cooldown: usize,
    },
    /// Budget steering: tracks the realized accumulated bit-ops of the
    /// trace it has emitted (the `schedule::cost` formula) and picks each
    /// step's `q_t` so the run lands on `target` × the static-`q_max`
    /// cost.
    CostGovernor {
        /// Target realized relative cost vs static `q_max`, in (0, 1].
        target: f64,
    },
}

impl PolicySpec {
    /// Default parameter set for a policy kind.
    pub fn default_for(kind: &str) -> Result<PolicySpec> {
        Ok(match kind {
            "static" => PolicySpec::StaticSuite,
            "loss_plateau" => PolicySpec::LossPlateau {
                ema: 0.5,
                patience: 2,
                min_delta: 0.01,
                q_step: 1.0,
                cooldown: 1,
            },
            "cost_governor" => PolicySpec::CostGovernor { target: 0.7 },
            other => bail!(
                "unknown policy '{other}' (known: static, loss_plateau, \
                 cost_governor)"
            ),
        })
    }

    /// Parse the compact syntax: `kind` or `kind:key=val,key=val`.
    pub fn parse(s: &str) -> Result<PolicySpec> {
        let (kind, args) = match s.split_once(':') {
            Some((k, a)) => (k.trim(), Some(a)),
            None => (s.trim(), None),
        };
        let mut spec = PolicySpec::default_for(kind)?;
        if let Some(args) = args {
            for part in args.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                let (k, v) = part.split_once('=').with_context(|| {
                    format!("policy parameter '{part}' is not key=value")
                })?;
                let v: f64 = v.trim().parse().with_context(|| {
                    format!("policy parameter '{k}' has non-numeric value")
                })?;
                spec.set(k.trim(), v)?;
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a `[sweep.policy]` table: `kind = "..."` plus one key per
    /// parameter. Unknown keys are rejected (a typo would otherwise be a
    /// silent result change).
    pub fn from_section(sec: &Section) -> Result<PolicySpec> {
        let kind = sec
            .get("kind")
            .context("policy table needs kind")?
            .as_str()?;
        let mut spec = PolicySpec::default_for(kind)?;
        for (k, v) in sec {
            if k == "kind" {
                continue;
            }
            spec.set(k, v.as_f64().with_context(|| format!("policy key '{k}'"))?)?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Set one parameter by name; rejects keys the variant does not have.
    fn set(&mut self, key: &str, v: f64) -> Result<()> {
        let as_count = |what: &str| -> Result<usize> {
            if v < 0.0 || v.fract() != 0.0 {
                bail!("policy parameter '{what}' must be a whole number >= 0");
            }
            Ok(v as usize)
        };
        match self {
            PolicySpec::StaticSuite => {
                bail!("policy 'static' takes no parameters (got '{key}')")
            }
            PolicySpec::LossPlateau {
                ema, patience, min_delta, q_step, cooldown,
            } => match key {
                "ema" => *ema = v,
                "patience" => *patience = as_count("patience")?,
                "min_delta" => *min_delta = v,
                "q_step" => *q_step = v,
                "cooldown" => *cooldown = as_count("cooldown")?,
                other => bail!(
                    "unknown loss_plateau parameter '{other}' (known: ema, \
                     patience, min_delta, q_step, cooldown)"
                ),
            },
            PolicySpec::CostGovernor { target } => match key {
                "target" => *target = v,
                other => bail!(
                    "unknown cost_governor parameter '{other}' (known: \
                     target)"
                ),
            },
        }
        Ok(())
    }

    /// Range checks — every parameter that could make a policy diverge or
    /// deadlock is fenced here, once, for all three input syntaxes.
    pub fn validate(&self) -> Result<()> {
        match *self {
            PolicySpec::StaticSuite => {}
            PolicySpec::LossPlateau {
                ema, patience, min_delta, q_step, ..
            } => {
                if ema.is_nan() || ema <= 0.0 || ema > 1.0 {
                    bail!("loss_plateau ema must be in (0, 1], got {ema}");
                }
                if patience == 0 {
                    bail!("loss_plateau patience must be >= 1");
                }
                if !(0.0..1.0).contains(&min_delta) {
                    bail!(
                        "loss_plateau min_delta must be in [0, 1), got \
                         {min_delta}"
                    );
                }
                if q_step.is_nan() || q_step <= 0.0 {
                    bail!("loss_plateau q_step must be > 0, got {q_step}");
                }
            }
            PolicySpec::CostGovernor { target } => {
                if target.is_nan() || target <= 0.0 || target > 1.0 {
                    bail!(
                        "cost_governor target must be in (0, 1], got {target}"
                    );
                }
            }
        }
        Ok(())
    }

    /// The canonical encoding: compact syntax with every parameter in
    /// sorted key order. This is what the sweep-spec hash consumes, so
    /// two specs are hash-equal iff they are value-equal.
    pub fn canonical(&self) -> String {
        match *self {
            PolicySpec::StaticSuite => "static".to_string(),
            PolicySpec::LossPlateau {
                ema, patience, min_delta, q_step, cooldown,
            } => format!(
                "loss_plateau:cooldown={cooldown},ema={ema},min_delta=\
                 {min_delta},patience={patience},q_step={q_step}"
            ),
            PolicySpec::CostGovernor { target } => {
                format!("cost_governor:target={target}")
            }
        }
    }

    /// Display label; adaptive sweeps use it as their single schedule-axis
    /// entry (the CSV `schedule` column).
    pub fn label(&self) -> &'static str {
        match self {
            PolicySpec::StaticSuite => "STATIC",
            PolicySpec::LossPlateau { .. } => "LOSS_PLATEAU",
            PolicySpec::CostGovernor { .. } => "COST_GOV",
        }
    }

    /// Does this policy choose `q_t` from feedback (true) or replay the
    /// cell's named schedule (false)?
    pub fn is_adaptive(&self) -> bool {
        !matches!(self, PolicySpec::StaticSuite)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml::TomlDoc;
    use crate::prop_assert;
    use crate::util::propcheck::propcheck;

    #[test]
    fn parse_defaults_and_overrides() {
        assert_eq!(PolicySpec::parse("static").unwrap(), PolicySpec::StaticSuite);
        let p = PolicySpec::parse("loss_plateau").unwrap();
        assert_eq!(p, PolicySpec::default_for("loss_plateau").unwrap());
        let p = PolicySpec::parse("loss_plateau:patience=4,ema=0.25").unwrap();
        match p {
            PolicySpec::LossPlateau { ema, patience, .. } => {
                assert_eq!(patience, 4);
                assert!((ema - 0.25).abs() < 1e-12);
            }
            other => panic!("{other:?}"),
        }
        let p = PolicySpec::parse("cost_governor:target=0.55").unwrap();
        assert_eq!(p, PolicySpec::CostGovernor { target: 0.55 });
    }

    #[test]
    fn parse_rejects_bad_input() {
        for bad in [
            "bogus",
            "static:x=1",
            "loss_plateau:nope=1",
            "loss_plateau:patience=1.5",
            "loss_plateau:patience",
            "loss_plateau:ema=zero",
            "loss_plateau:ema=0",
            "loss_plateau:ema=1.5",
            "loss_plateau:patience=0",
            "loss_plateau:min_delta=1",
            "loss_plateau:q_step=0",
            "cost_governor:target=0",
            "cost_governor:target=1.2",
            "cost_governor:nope=1",
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn canonical_round_trips() {
        propcheck(200, |rng| {
            let spec = match rng.below(3) {
                0 => PolicySpec::StaticSuite,
                1 => PolicySpec::LossPlateau {
                    ema: 0.05 + 0.95 * rng.next_f32() as f64,
                    patience: 1 + rng.below(6) as usize,
                    min_delta: 0.25 * rng.next_f32() as f64,
                    q_step: 0.5 + rng.below(4) as f64 * 0.5,
                    cooldown: rng.below(4) as usize,
                },
                _ => PolicySpec::CostGovernor {
                    target: 0.05 + 0.95 * rng.next_f32() as f64,
                },
            };
            let back = PolicySpec::parse(&spec.canonical())
                .map_err(|e| format!("{e:#}"))?;
            prop_assert!(
                back == spec,
                "canonical round-trip changed the spec: {spec:?} -> {back:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn from_section_reads_policy_tables() {
        let doc = TomlDoc::parse(
            "[sweep.policy]\nkind = \"loss_plateau\"\npatience = 3\n\
             min_delta = 0.02",
        )
        .unwrap();
        let p = PolicySpec::from_section(doc.section("sweep.policy").unwrap())
            .unwrap();
        match p {
            PolicySpec::LossPlateau { patience, min_delta, ema, .. } => {
                assert_eq!(patience, 3);
                assert!((min_delta - 0.02).abs() < 1e-12);
                assert!((ema - 0.5).abs() < 1e-12, "default kept");
            }
            other => panic!("{other:?}"),
        }
        // unknown keys and missing kind are rejected
        let doc = TomlDoc::parse("[sweep.policy]\nkind = \"loss_plateau\"\nnope = 1")
            .unwrap();
        assert!(
            PolicySpec::from_section(doc.section("sweep.policy").unwrap())
                .is_err()
        );
        let doc = TomlDoc::parse("[sweep.policy]\npatience = 3").unwrap();
        assert!(
            PolicySpec::from_section(doc.section("sweep.policy").unwrap())
                .is_err()
        );
    }

    #[test]
    fn labels_and_adaptivity() {
        assert!(!PolicySpec::StaticSuite.is_adaptive());
        assert!(PolicySpec::parse("loss_plateau").unwrap().is_adaptive());
        assert!(PolicySpec::parse("cost_governor").unwrap().is_adaptive());
        assert_eq!(
            PolicySpec::parse("loss_plateau").unwrap().label(),
            "LOSS_PLATEAU"
        );
        assert_eq!(
            PolicySpec::parse("cost_governor").unwrap().label(),
            "COST_GOV"
        );
    }
}

//! Budget-steered precision: hit a target realized relative cost.
//!
//! The paper reports every schedule's *relative cost* against the static
//! q_max baseline under its BitOps formula (see `schedule::cost`):
//!
//!   cost(t) ∝ q_t² + 2·q_max·q_t        (fwd + 2 bwd GEMMs, q_bwd = q_max)
//!
//! The governor turns that accounting into a control loop: given a target
//! relative cost ρ, the total budget is ρ·T·3·q_max². Before each step it
//! divides the *remaining* budget by the remaining steps and solves the
//! per-step cost equation for q —
//!
//!   q² + 2·q_max·q = allowance   ⇒   q = √(q_max² + allowance) − q_max
//!
//! — clamps to [q_min, q_max], rounds to integer bits, and charges the
//! rounded step's exact cost back against the budget. Rounding surpluses
//! and clamp losses therefore feed back immediately: the realized trace
//! dithers between adjacent bit-widths and lands on the target to within
//! one step's cost (propcheck-tested). The trace is exact *realized*
//! accounting — the policy charges the integer precisions the trainer
//! actually runs, not a schedule-mean estimate.
//!
//! Deterministic and feedback-free: the emitted trace is a pure function
//! of (q_min, q_max, target, total_steps), so it needs no loss signal —
//! it is the "budget axis" counterpart to [`super::LossPlateauPolicy`]'s
//! loss axis.

use super::{ChunkFeedback, PrecisionPolicy};

pub struct CostGovernorPolicy {
    q_min: f64,
    q_max: f64,
    total_steps: usize,
    /// ρ·T·3·q_max² — the run's total cost allowance.
    budget: f64,
    /// Exact cost of the integer trace emitted so far.
    spent: f64,
    emitted: usize,
}

impl CostGovernorPolicy {
    pub fn new(
        q_min: f64,
        q_max: f64,
        target: f64,
        total_steps: usize,
    ) -> CostGovernorPolicy {
        CostGovernorPolicy {
            q_min,
            q_max,
            total_steps,
            budget: target * total_steps as f64 * 3.0 * q_max * q_max,
            spent: 0.0,
            emitted: 0,
        }
    }

    /// Step cost under the paper's formula (q_bwd pinned to q_max).
    fn step_cost(&self, q: f64) -> f64 {
        q * q + 2.0 * self.q_max * q
    }
}

impl PrecisionPolicy for CostGovernorPolicy {
    fn q_chunk(&mut self, _start: usize, len: usize) -> Vec<f32> {
        let mut qs = Vec::with_capacity(len);
        for _ in 0..len {
            let remaining_steps =
                self.total_steps.saturating_sub(self.emitted).max(1);
            let allowance =
                ((self.budget - self.spent) / remaining_steps as f64).max(0.0);
            let q_star =
                (self.q_max * self.q_max + allowance).sqrt() - self.q_max;
            let q = q_star.clamp(self.q_min, self.q_max).round().max(1.0);
            self.spent += self.step_cost(q);
            self.emitted += 1;
            qs.push(q as f32);
        }
        qs
    }

    /// The governor steers on its own emitted trace (which *is* the
    /// realized trace — the trainer runs exactly these precisions), so
    /// loss feedback is deliberately unused.
    fn observe(&mut self, _fb: ChunkFeedback) {}

    fn label(&self) -> &'static str {
        "COST_GOV"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::schedule::cost::relative_cost_of_trace;
    use crate::util::propcheck::propcheck;

    /// Drive a governor to completion under a random chunking and return
    /// the integer trace.
    fn trace(
        q_min: f64,
        q_max: f64,
        target: f64,
        total: usize,
        rng: &mut crate::util::prng::Pcg32,
    ) -> Vec<u32> {
        let mut p = CostGovernorPolicy::new(q_min, q_max, target, total);
        let mut qs = Vec::with_capacity(total);
        let mut step = 0usize;
        while step < total {
            let k = (1 + rng.below(9) as usize).min(total - step);
            for q in p.q_chunk(step, k) {
                qs.push(q as u32);
            }
            step += k;
        }
        qs
    }

    #[test]
    fn realized_cost_lands_on_the_target() {
        propcheck(150, |rng| {
            let q_min = 2.0 + rng.below(3) as f64;
            let q_max = q_min + 2.0 + rng.below(5) as f64;
            let total = 64 + rng.below(400) as usize;
            // targets inside the achievable band for [q_min, q_max]
            let lo = (q_min * q_min + 2.0 * q_max * q_min)
                / (3.0 * q_max * q_max);
            let target = lo + (1.0 - lo) * (0.1 + 0.8 * rng.next_f32() as f64);
            let qs = trace(q_min, q_max, target, total, rng);
            prop_assert!(qs.len() == total, "trace length");
            for &q in &qs {
                prop_assert!(
                    q as f64 >= q_min - 0.5 && q as f64 <= q_max + 0.5,
                    "q={q} outside [{q_min}, {q_max}]"
                );
            }
            let realized = relative_cost_of_trace(&qs, q_max);
            // within one step's worth of relative cost (the rounding
            // granularity), plus a little float slack
            let tol = 1.0 / total as f64 + 0.02;
            prop_assert!(
                (realized - target).abs() <= tol,
                "realized {realized:.4} vs target {target:.4} (tol {tol:.4})"
            );
            Ok(())
        });
    }

    #[test]
    fn unreachable_targets_clamp_to_the_bounds() {
        let mut rng = crate::util::prng::Pcg32::new(7, 7);
        // a target cheaper than static q_min: every step clamps to q_min
        let qs = trace(3.0, 8.0, 0.05, 128, &mut rng);
        assert!(qs.iter().all(|&q| q == 3), "{qs:?}");
        // a target of 1.0 (static q_max cost): every step runs at q_max
        let qs = trace(3.0, 8.0, 1.0, 128, &mut rng);
        assert!(qs.iter().all(|&q| q == 8), "{qs:?}");
    }

    #[test]
    fn trace_is_deterministic_and_chunking_independent() {
        let mut a = CostGovernorPolicy::new(3.0, 8.0, 0.6, 100);
        let one: Vec<f32> = (0..100).flat_map(|t| a.q_chunk(t, 1)).collect();
        let mut b = CostGovernorPolicy::new(3.0, 8.0, 0.6, 100);
        let mut chunked = Vec::new();
        let mut step = 0;
        for k in [7usize, 13, 20, 20, 20, 20] {
            let k = k.min(100 - step);
            chunked.extend(b.q_chunk(step, k));
            step += k;
        }
        assert_eq!(one, chunked, "emission must not depend on chunk splits");
        // dithering between adjacent widths, not a constant
        let distinct: std::collections::BTreeSet<u32> =
            one.iter().map(|&q| q as u32).collect();
        assert!(distinct.len() >= 2, "expected dithering, got {distinct:?}");
    }
}

//! Plateau-triggered precision switching (MuPPET-style).
//!
//! MuPPET trains in fp8→fp16→fp32 stages and switches up when its
//! gradient-diversity statistic stalls; the analogue on this testbed's
//! signal set is the EMA of the per-chunk training loss. The policy holds
//! the lowest usable precision and *raises* it by `q_step` bits whenever
//! the EMA stops improving for `patience` consecutive chunks — cheap
//! early training, precision spent only when the optimizer demonstrably
//! needs it. Hysteresis comes from two knobs: `min_delta` (an improvement
//! must beat the best EMA by a relative margin to count) and `cooldown`
//! (chunks ignored right after a switch, while the loss re-equilibrates
//! at the new precision).
//!
//! Deterministic: state is a pure fold over the observed feedback
//! sequence.

use super::{ChunkFeedback, PrecisionPolicy};

pub struct LossPlateauPolicy {
    /// Current precision in bits (continuous; emitted rounded).
    q: f64,
    q_max: f64,
    /// EMA smoothing factor in (0, 1].
    alpha: f64,
    patience: usize,
    min_delta: f64,
    q_step: f64,
    cooldown: usize,
    /// EMA of chunk mean loss (None before the first observation).
    ema_loss: Option<f64>,
    /// Best EMA seen since the last switch.
    best: f64,
    /// Consecutive chunks without a qualifying improvement.
    stale: usize,
    cooldown_left: usize,
}

impl LossPlateauPolicy {
    pub fn new(
        q_min: f64,
        q_max: f64,
        ema: f64,
        patience: usize,
        min_delta: f64,
        q_step: f64,
        cooldown: usize,
    ) -> LossPlateauPolicy {
        LossPlateauPolicy {
            q: q_min,
            q_max,
            alpha: ema,
            patience,
            min_delta,
            q_step,
            cooldown,
            ema_loss: None,
            best: f64::INFINITY,
            stale: 0,
            cooldown_left: 0,
        }
    }

    /// Current precision in integer bits.
    pub fn current_q(&self) -> u32 {
        self.q.round().max(1.0) as u32
    }
}

impl PrecisionPolicy for LossPlateauPolicy {
    fn q_chunk(&mut self, _start: usize, len: usize) -> Vec<f32> {
        vec![self.current_q() as f32; len]
    }

    fn observe(&mut self, fb: ChunkFeedback) {
        // a diverged chunk (NaN/inf loss) counts as "no improvement"
        // without poisoning the EMA state
        let loss = if fb.mean_loss.is_finite() {
            fb.mean_loss as f64
        } else {
            f64::INFINITY
        };
        let ema = match (self.ema_loss, loss.is_finite()) {
            (Some(e), true) => self.alpha * loss + (1.0 - self.alpha) * e,
            (Some(e), false) => e,
            (None, true) => loss,
            (None, false) => return, // nothing observable yet
        };
        self.ema_loss = Some(ema);
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return;
        }
        // relative-margin improvement test; losses on this testbed are
        // positive (CE / MSE-style), which the margin arithmetic assumes
        let improved = loss.is_finite() && ema < self.best * (1.0 - self.min_delta);
        if improved {
            self.best = ema;
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.patience && self.q < self.q_max {
                self.q = (self.q + self.q_step).min(self.q_max);
                self.stale = 0;
                self.cooldown_left = self.cooldown;
                // reset the baseline: the new precision gets a fresh
                // chance to show progress before the next switch
                self.best = ema;
            }
        }
    }

    fn label(&self) -> &'static str {
        "LOSS_PLATEAU"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(step: usize, mean_loss: f32) -> ChunkFeedback {
        ChunkFeedback {
            step,
            len: 4,
            last_loss: mean_loss,
            mean_loss,
            loss_volatility: 0.0,
        }
    }

    #[test]
    fn starts_at_q_min_and_emits_constant_chunks() {
        let mut p = LossPlateauPolicy::new(3.0, 8.0, 1.0, 2, 0.0, 1.0, 0);
        assert_eq!(p.q_chunk(0, 4), vec![3.0f32; 4]);
        assert_eq!(p.current_q(), 3);
    }

    #[test]
    fn improvement_holds_precision_plateau_raises_it() {
        // alpha 1 (no smoothing), patience 2, no margin, no cooldown
        let mut p = LossPlateauPolicy::new(3.0, 8.0, 1.0, 2, 0.0, 1.0, 0);
        for (t, l) in [2.0f32, 1.5, 1.2].iter().enumerate() {
            p.observe(fb(t, *l));
        }
        assert_eq!(p.current_q(), 3, "improving loss must hold q");
        p.observe(fb(3, 1.2)); // stale 1
        assert_eq!(p.current_q(), 3);
        p.observe(fb(4, 1.2)); // stale 2 >= patience -> raise
        assert_eq!(p.current_q(), 4);
        // baseline reset: a new improvement streak holds q at 4
        p.observe(fb(5, 1.1));
        p.observe(fb(6, 1.0));
        assert_eq!(p.current_q(), 4);
    }

    #[test]
    fn min_delta_is_a_hysteresis_band() {
        // 1% margin: a 0.5% improvement per chunk counts as stale
        let mut p = LossPlateauPolicy::new(3.0, 8.0, 1.0, 2, 0.01, 1.0, 0);
        let mut loss = 1.0f32;
        p.observe(fb(0, loss));
        for t in 1..4 {
            loss *= 0.995;
            p.observe(fb(t, loss));
        }
        assert_eq!(p.current_q(), 4, "sub-margin progress is a plateau");
    }

    #[test]
    fn cooldown_suppresses_back_to_back_switches() {
        let mut p = LossPlateauPolicy::new(3.0, 8.0, 1.0, 1, 0.0, 1.0, 2);
        p.observe(fb(0, 1.0));
        p.observe(fb(1, 1.0)); // stale 1 >= patience -> q=4, cooldown=2
        assert_eq!(p.current_q(), 4);
        p.observe(fb(2, 1.0)); // cooldown
        p.observe(fb(3, 1.0)); // cooldown
        assert_eq!(p.current_q(), 4);
        p.observe(fb(4, 1.0)); // stale again -> q=5
        assert_eq!(p.current_q(), 5);
    }

    #[test]
    fn clamps_at_q_max_and_survives_nan() {
        let mut p = LossPlateauPolicy::new(7.0, 8.0, 1.0, 1, 0.0, 4.0, 0);
        p.observe(fb(0, 1.0));
        p.observe(fb(1, 1.0)); // raise by 4, clamped to 8
        assert_eq!(p.current_q(), 8);
        p.observe(fb(2, f32::NAN)); // must not panic or move q above max
        p.observe(fb(3, 1.0));
        assert_eq!(p.current_q(), 8);
    }

    #[test]
    fn nan_chunks_count_as_stale_not_as_progress() {
        let mut p = LossPlateauPolicy::new(3.0, 8.0, 1.0, 2, 0.0, 1.0, 0);
        p.observe(fb(0, 1.0));
        p.observe(fb(1, f32::NAN));
        p.observe(fb(2, f32::INFINITY));
        assert_eq!(p.current_q(), 4, "diverged chunks are a plateau signal");
    }
}

//! Adaptive precision policies — feedback-driven `q_t` selection.
//!
//! The paper fixes its CPT schedules up front; this subsystem makes the
//! precision trajectory a *decision process*: a [`PrecisionPolicy`]
//! observes per-chunk training signals (loss, loss EMA/delta, a
//! gradient-noise proxy, the step budget) and emits the next chunk's
//! precision. The trainer's loop becomes
//!
//! ```text
//!   q = policy.q_chunk(step, k)      # before the chunk executes
//!   ... run k steps at q ...
//!   policy.observe(feedback)         # losses of the executed chunk
//! ```
//!
//! Three deterministic implementations ship:
//! * [`StaticPolicy`] replays a precomputed [`Schedule`] and ignores all
//!   feedback — the legacy path is one policy among many, and its chunked
//!   emission is propcheck-tested pointwise identical to
//!   [`Schedule::q_vec`], so wrapping a schedule in a policy changes no
//!   result bit;
//! * [`LossPlateauPolicy`] raises precision on loss-EMA plateaus
//!   (MuPPET-style switching with patience + hysteresis);
//! * [`CostGovernorPolicy`] steers `q_t` to land the run on a target
//!   realized relative cost (the `schedule::cost` formula).
//!
//! **Determinism contract.** A policy must be a pure function of its
//! [`PolicySpec`] parameters and the feedback sequence it has observed —
//! no clocks, no RNG, no global state. Training itself is deterministic
//! per cell (fixed seeds), so the realized trace of an adaptive run is
//! reproducible, which is what lets adaptive cells shard, resume, and
//! merge byte-identically: a cell is recomputed either never (artifact
//! reuse) or from step zero, never from the middle of a trace. The
//! result-determining identity of a policy is [`PolicySpec::canonical`],
//! which the sweep-spec hash consumes (see rust/DESIGN-policy.md).

pub mod cost_governor;
pub mod loss_plateau;
pub mod spec;

pub use cost_governor::CostGovernorPolicy;
pub use loss_plateau::LossPlateauPolicy;
pub use spec::PolicySpec;

use anyhow::{bail, Result};

use crate::schedule::Schedule;

/// Training signals of one executed chunk, fed to the policy before the
/// next chunk's precision is requested.
#[derive(Clone, Copy, Debug)]
pub struct ChunkFeedback {
    /// First optimizer step of the executed chunk.
    pub step: usize,
    /// Steps in the chunk.
    pub len: usize,
    /// Training loss at the chunk's last step.
    pub last_loss: f32,
    /// Mean training loss over the chunk.
    pub mean_loss: f32,
    /// Gradient-noise proxy: mean |loss[i+1] − loss[i]| within the chunk
    /// (0 for single-step chunks). High volatility at low precision is
    /// the classic symptom of quantization noise drowning the gradient
    /// signal.
    pub loss_volatility: f32,
}

impl ChunkFeedback {
    /// Fold an executed chunk's per-step training losses into the
    /// feedback signals. The single definition of the mean/volatility
    /// fold — the trainer, the policy-trace replay, and the fabricated
    /// test simulators all build feedback through here, so they can
    /// never drift apart. `losses` must be non-empty.
    pub fn from_losses(step: usize, losses: &[f32]) -> ChunkFeedback {
        let k = losses.len();
        let mean_loss = losses.iter().sum::<f32>() / k as f32;
        let loss_volatility = if k > 1 {
            losses.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f32>()
                / (k - 1) as f32
        } else {
            0.0
        };
        ChunkFeedback {
            step,
            len: k,
            last_loss: losses[k - 1],
            mean_loss,
            loss_volatility,
        }
    }
}

/// A precision decision process: called once per chunk, fed back once per
/// chunk. See the module docs for the determinism contract.
pub trait PrecisionPolicy {
    /// Integer-valued precisions (as f32, the trainer's wire format) for
    /// the upcoming chunk `[start, start + len)`.
    fn q_chunk(&mut self, start: usize, len: usize) -> Vec<f32>;

    /// Observe the executed chunk's training signals.
    fn observe(&mut self, fb: ChunkFeedback);

    /// Short display label (the CSV `schedule` column for adaptive runs).
    fn label(&self) -> &'static str;
}

/// The legacy path as a policy: replay a precomputed schedule, ignore all
/// feedback.
pub struct StaticPolicy {
    schedule: Schedule,
}

impl StaticPolicy {
    pub fn new(schedule: Schedule) -> StaticPolicy {
        StaticPolicy { schedule }
    }
}

impl PrecisionPolicy for StaticPolicy {
    fn q_chunk(&mut self, start: usize, len: usize) -> Vec<f32> {
        self.schedule.q_vec(start, len)
    }

    fn observe(&mut self, _fb: ChunkFeedback) {}

    fn label(&self) -> &'static str {
        "STATIC"
    }
}

impl PolicySpec {
    /// Instantiate an adaptive policy over `[q_min, q_max]` for a run of
    /// `total_steps`. `StaticSuite` has no adaptive instantiation — the
    /// caller wraps its schedule in [`StaticPolicy`] instead (it needs
    /// the cell's schedule, which this spec deliberately knows nothing
    /// about).
    pub fn build_adaptive(
        &self,
        q_min: f64,
        q_max: f64,
        total_steps: usize,
    ) -> Result<Box<dyn PrecisionPolicy>> {
        self.validate()?;
        if q_min > q_max {
            bail!("policy bounds: q_min {q_min} > q_max {q_max}");
        }
        if total_steps == 0 {
            bail!("policy needs total_steps >= 1");
        }
        Ok(match *self {
            PolicySpec::StaticSuite => bail!(
                "'static' is not an adaptive policy — it replays the \
                 cell's named schedule"
            ),
            PolicySpec::LossPlateau {
                ema, patience, min_delta, q_step, cooldown,
            } => Box::new(LossPlateauPolicy::new(
                q_min, q_max, ema, patience, min_delta, q_step, cooldown,
            )),
            PolicySpec::CostGovernor { target } => Box::new(
                CostGovernorPolicy::new(q_min, q_max, target, total_steps),
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::schedule::suite;
    use crate::util::propcheck::propcheck;

    /// The StaticSuite equivalence bar: chunked policy emission, with
    /// arbitrary chunk splits and arbitrary interleaved feedback, equals
    /// Schedule::q_vec pointwise — the legacy schedule path reproduced
    /// bit-identically through the policy machinery.
    #[test]
    fn static_policy_matches_schedule_pointwise_under_any_chunking() {
        propcheck(200, |rng| {
            let names = suite::suite_names();
            let name = names[rng.below(names.len() as u32) as usize];
            let total = 16 + rng.below(400) as usize;
            let n = 2 * (1 + rng.below(4) as usize);
            let q_min = 2.0 + rng.below(4) as f64;
            let q_max = q_min + 1.0 + rng.below(6) as f64;
            let sched = suite::by_name(name, q_min, q_max, total, n)
                .map_err(|e| format!("{e:#}"))?;
            let want = sched.q_vec(0, total);
            let mut policy = StaticPolicy::new(sched);
            let mut got = Vec::with_capacity(total);
            let mut step = 0usize;
            while step < total {
                let k = (1 + rng.below(9) as usize).min(total - step);
                let qs = policy.q_chunk(step, k);
                prop_assert!(qs.len() == k, "chunk length {} != {k}", qs.len());
                got.extend_from_slice(&qs);
                // feedback is ignored by construction — feed noise to
                // prove it cannot perturb the emission
                policy.observe(ChunkFeedback {
                    step,
                    len: k,
                    last_loss: rng.next_f32(),
                    mean_loss: rng.next_f32(),
                    loss_volatility: rng.next_f32(),
                });
                step += k;
            }
            prop_assert!(got == want, "chunked emission differs from q_vec");
            Ok(())
        });
    }

    #[test]
    fn build_adaptive_rejects_static_and_bad_bounds() {
        let err = PolicySpec::StaticSuite
            .build_adaptive(3.0, 8.0, 100)
            .unwrap_err();
        assert!(err.to_string().contains("not an adaptive"), "{err:#}");
        let p = PolicySpec::parse("loss_plateau").unwrap();
        assert!(p.build_adaptive(8.0, 3.0, 100).is_err());
        assert!(p.build_adaptive(3.0, 8.0, 0).is_err());
        assert!(p.build_adaptive(3.0, 8.0, 100).is_ok());
        let g = PolicySpec::parse("cost_governor").unwrap();
        assert_eq!(g.build_adaptive(3.0, 8.0, 100).unwrap().label(), "COST_GOV");
    }
}

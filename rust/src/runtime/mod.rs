//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API) following the
//! /opt/xla-example/load_hlo pattern: HLO *text* -> HloModuleProto ->
//! XlaComputation -> compile -> execute. Text is the interchange format
//! because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized
//! protos.
//!
//! `LoadedModel` exposes the four entry points of each exported model and
//! owns the training state (flat param/opt vectors) as host literals
//! between calls. The PJRT shim returns outputs as a single tuple literal
//! (untuple_result=false in the C layer), so a host roundtrip per call is
//! unavoidable; the train-*chunk* artifact amortizes it over K optimizer
//! steps (see DESIGN.md §2 and EXPERIMENTS.md §Perf).

pub mod artifact;

pub use artifact::{DType, DataInput, Manifest, ModelSpec};

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared PJRT client (CPU). One per process.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact.
    pub fn compile_file(&self, path: &Path) -> Result<CompiledFn> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", path.display()))?;
        Ok(CompiledFn { exe, compile_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// Load a model's four entry points from the manifest.
    pub fn load_model(&self, spec: &ModelSpec) -> Result<LoadedModel> {
        spec.validate()?;
        let get = |tag: &str| -> Result<CompiledFn> {
            self.compile_file(spec.files.get(tag).unwrap())
        };
        Ok(LoadedModel {
            spec: spec.clone(),
            init: get("init")?,
            train_chunk: get("train_chunk")?,
            train_step: get("train_step")?,
            eval: get("eval")?,
        })
    }
}

/// One compiled executable.
pub struct CompiledFn {
    exe: PjRtLoadedExecutable,
    pub compile_ms: f64,
}

impl CompiledFn {
    /// Execute and untuple the single tuple output into literals.
    pub fn call(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let outs = self.exe.execute::<Literal>(args)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---------------------------------------------------------------- literals

/// f32 literal with shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elems, got {}", shape, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal with shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elems, got {}", shape, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Host-side tensor (used by the data generators).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => s,
        }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostTensor::F32(s, d) => lit_f32(s, d),
            HostTensor::I32(s, d) => lit_i32(s, d),
        }
    }

    /// Stack K same-shaped tensors along a new leading axis.
    pub fn stack(ts: &[HostTensor]) -> Result<HostTensor> {
        let first = ts.first().context("empty stack")?;
        let mut shape = vec![ts.len()];
        shape.extend_from_slice(first.shape());
        match first {
            HostTensor::F32(s0, _) => {
                let mut data =
                    Vec::with_capacity(s0.iter().product::<usize>() * ts.len());
                for t in ts {
                    match t {
                        HostTensor::F32(s, d) if s == s0 => {
                            data.extend_from_slice(d)
                        }
                        _ => bail!("stack: mismatched tensors"),
                    }
                }
                Ok(HostTensor::F32(shape, data))
            }
            HostTensor::I32(s0, _) => {
                let mut data =
                    Vec::with_capacity(s0.iter().product::<usize>() * ts.len());
                for t in ts {
                    match t {
                        HostTensor::I32(s, d) if s == s0 => {
                            data.extend_from_slice(d)
                        }
                        _ => bail!("stack: mismatched tensors"),
                    }
                }
                Ok(HostTensor::I32(shape, data))
            }
        }
    }
}

/// Training state: flat parameter + optimizer-state vectors, kept as host
/// literals between chunk calls.
pub struct TrainState {
    pub params: Literal,
    pub opt_state: Literal,
    /// Optimizer steps taken so far.
    pub step: usize,
}

/// A fully-loaded model with its four entry points.
pub struct LoadedModel {
    pub spec: ModelSpec,
    pub init: CompiledFn,
    pub train_chunk: CompiledFn,
    pub train_step: CompiledFn,
    pub eval: CompiledFn,
}

/// Per-chunk step results.
#[derive(Clone, Debug)]
pub struct ChunkResult {
    pub losses: Vec<f32>,
    pub metrics: Vec<f32>,
}

impl LoadedModel {
    /// Run the init artifact; returns fresh training state.
    pub fn init_state(&self, seed: i32) -> Result<TrainState> {
        let outs = self.init.call(&[scalar_i32(seed)])?;
        if outs.len() != 2 {
            bail!("init returned {} outputs, want 2", outs.len());
        }
        let mut it = outs.into_iter();
        Ok(TrainState {
            params: it.next().unwrap(),
            opt_state: it.next().unwrap(),
            step: 0,
        })
    }

    /// Advance `k` optimizer steps (k = spec.chunk for the chunk artifact,
    /// 1 for the step artifact). `stacked` are the K-step minibatch
    /// tensors (with leading K axis for the chunk call), `shared` the
    /// per-chunk tensors, `q_fwd`/`lr`/`seeds` the per-step vectors.
    #[allow(clippy::too_many_arguments)]
    pub fn advance(
        &self,
        state: &mut TrainState,
        k: usize,
        stacked: Vec<Literal>,
        shared: Vec<Literal>,
        q_fwd: &[f32],
        lr: &[f32],
        seeds: &[i32],
        q_bwd: f32,
    ) -> Result<ChunkResult> {
        if q_fwd.len() != k || lr.len() != k || seeds.len() != k {
            bail!(
                "advance(k={k}): vector lengths q={} lr={} seeds={}",
                q_fwd.len(),
                lr.len(),
                seeds.len()
            );
        }
        let exe = if k == self.spec.chunk {
            &self.train_chunk
        } else if k == 1 {
            &self.train_step
        } else {
            bail!("advance: k={k} (chunk={}, step=1 only)", self.spec.chunk)
        };

        let mut args: Vec<Literal> =
            Vec::with_capacity(stacked.len() + shared.len() + 6);
        args.push(clone_literal(&state.params)?);
        args.push(clone_literal(&state.opt_state)?);
        args.extend(stacked);
        args.extend(shared);
        args.push(lit_f32(&[k], q_fwd)?);
        args.push(lit_f32(&[k], lr)?);
        args.push(lit_i32(&[k], seeds)?);
        args.push(scalar_f32(q_bwd));

        let outs = exe.call(&args)?;
        if outs.len() != 4 {
            bail!("train returned {} outputs, want 4", outs.len());
        }
        let mut it = outs.into_iter();
        state.params = it.next().unwrap();
        state.opt_state = it.next().unwrap();
        state.step += k;
        let losses = it.next().unwrap().to_vec::<f32>()?;
        let metrics = it.next().unwrap().to_vec::<f32>()?;
        Ok(ChunkResult { losses, metrics })
    }

    /// Evaluate on one batch; returns (loss, metric).
    pub fn evaluate(
        &self,
        state: &TrainState,
        data: Vec<Literal>,
    ) -> Result<(f32, f32)> {
        let mut args = Vec::with_capacity(data.len() + 1);
        args.push(clone_literal(&state.params)?);
        args.extend(data);
        let outs = self.eval.call(&args)?;
        if outs.len() != 2 {
            bail!("eval returned {} outputs, want 2", outs.len());
        }
        let loss = outs[0].get_first_element::<f32>()?;
        let metric = outs[1].get_first_element::<f32>()?;
        Ok((loss, metric))
    }
}

/// The xla crate's Literal has no Clone; round-trip through host data.
pub fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>()?;
            Ok(Literal::vec1(&v).reshape(&dims)?)
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>()?;
            Ok(Literal::vec1(&v).reshape(&dims)?)
        }
        t => bail!("clone_literal: unsupported type {t:?}"),
    }
}

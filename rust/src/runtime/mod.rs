//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API) following the
//! /opt/xla-example/load_hlo pattern: HLO *text* -> HloModuleProto ->
//! XlaComputation -> compile -> execute. Text is the interchange format
//! because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id serialized
//! protos.
//!
//! `LoadedModel` exposes the four entry points of each exported model.
//! Training state lives on the *host* as flat `Vec<f32>` buffers
//! (`HostVec`) between calls: the PJRT shim returns outputs as a single
//! tuple literal (untuple_result=false in the C layer), so one
//! host-download per call is unavoidable — but the upload side is a
//! single `Literal` build per `advance`, with **no** `clone_literal`
//! roundtrips on the hot path (see rust/DESIGN-perf.md). Executables
//! take arguments by reference (`call_refs`), so shared/eval literals
//! can be cached by the trainer and reused across chunk calls. The
//! train-*chunk* artifact amortizes the per-call cost over K optimizer
//! steps (see DESIGN.md §2 and EXPERIMENTS.md §Perf).

pub mod artifact;

pub use artifact::{DType, DataInput, Manifest, ModelSpec};

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared PJRT client (CPU). One per process *thread-domain*: PJRT
/// handles are not Sync, so the parallel sweep executor builds one
/// Runtime per worker thread.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact.
    pub fn compile_file(&self, path: &Path) -> Result<CompiledFn> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", path.display()))?;
        Ok(CompiledFn { exe, compile_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// Load a model's four entry points from the manifest.
    pub fn load_model(&self, spec: &ModelSpec) -> Result<LoadedModel> {
        spec.validate()?;
        let get = |tag: &str| -> Result<CompiledFn> {
            self.compile_file(spec.files.get(tag).unwrap())
        };
        Ok(LoadedModel {
            spec: spec.clone(),
            init: get("init")?,
            train_chunk: get("train_chunk")?,
            train_step: get("train_step")?,
            eval: get("eval")?,
        })
    }

    /// Serialize a loaded model's compiled entry points into
    /// `(tag, bytes)` payloads for the AOT disk cache
    /// (`coordinator::aot`). Gated on [`exec_serialization_support`]:
    /// this is the single seam where a binding with
    /// `PjRtLoadedExecutable` serialization would turn entry points
    /// into payload bytes.
    pub fn serialize_model(
        &self,
        _model: &LoadedModel,
    ) -> Result<Vec<(String, Vec<u8>)>> {
        exec_serialization_support()
            .map_err(|reason| anyhow!("cannot serialize executables: {reason}"))?;
        bail!("serialization probe passed but no executable codec is wired")
    }

    /// Rebuild a [`LoadedModel`] from cached payload bytes — the
    /// counterpart of [`Runtime::serialize_model`], behind the same
    /// capability gate.
    pub fn load_model_from_bytes(
        &self,
        _spec: &ModelSpec,
        _payloads: &[(String, Vec<u8>)],
    ) -> Result<LoadedModel> {
        exec_serialization_support().map_err(|reason| {
            anyhow!("cannot deserialize executables: {reason}")
        })?;
        bail!("serialization probe passed but no executable codec is wired")
    }
}

/// Capability probe: can this build serialize and deserialize PJRT
/// executables at all? Checked once at executor startup so a configured
/// AOT cache (`CPT_AOT_CACHE`) degrades to plain compiles with a single
/// note instead of failing per cell. The vendored `xla` binding
/// (xla_extension 0.5.1) exposes compile/execute but no
/// `PjRtLoadedExecutable` serialization surface, so this build reports
/// unsupported; the disk-store layer (`coordinator::aot`) is exercised
/// at the bytes level by its own tests and fabricated runners.
pub fn exec_serialization_support() -> std::result::Result<(), &'static str> {
    Err("the vendored xla binding (xla_extension 0.5.1) exposes no PJRT \
         executable serialization API")
}

/// One compiled executable.
pub struct CompiledFn {
    exe: PjRtLoadedExecutable,
    pub compile_ms: f64,
}

impl CompiledFn {
    /// Execute and untuple the single tuple output into literals.
    pub fn call(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let refs: Vec<&Literal> = args.iter().collect();
        self.call_refs(&refs)
    }

    /// Execute with borrowed arguments — lets callers keep literals
    /// cached across calls instead of rebuilding (or cloning) them.
    pub fn call_refs(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let outs = self.exe.execute::<&Literal>(args)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---------------------------------------------------------------- literals

/// f32 literal with shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elems, got {}", shape, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// i32 literal with shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {:?} wants {n} elems, got {}", shape, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

pub fn scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Host-side tensor (used by the data generators).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => s,
        }
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            HostTensor::F32(s, d) => lit_f32(s, d),
            HostTensor::I32(s, d) => lit_i32(s, d),
        }
    }

    /// Stack K same-shaped tensors along a new leading axis.
    ///
    /// Allocates a fresh buffer per call; the hot path uses
    /// [`LiteralArena::stack_literal`] instead, which writes into
    /// reusable scratch memory.
    pub fn stack(ts: &[HostTensor]) -> Result<HostTensor> {
        let first = ts.first().context("stack: empty input")?;
        let mut shape = vec![ts.len()];
        shape.extend_from_slice(first.shape());
        match first {
            HostTensor::F32(s0, _) => {
                let mut data =
                    Vec::with_capacity(s0.iter().product::<usize>() * ts.len());
                for t in ts {
                    match t {
                        HostTensor::F32(s, d) => {
                            if s != s0 {
                                bail!(
                                    "stack: shape mismatch ({s:?} vs {s0:?})"
                                );
                            }
                            data.extend_from_slice(d);
                        }
                        HostTensor::I32(..) => {
                            bail!("stack: dtype mismatch (i32 among f32)")
                        }
                    }
                }
                Ok(HostTensor::F32(shape, data))
            }
            HostTensor::I32(s0, _) => {
                let mut data =
                    Vec::with_capacity(s0.iter().product::<usize>() * ts.len());
                for t in ts {
                    match t {
                        HostTensor::I32(s, d) => {
                            if s != s0 {
                                bail!(
                                    "stack: shape mismatch ({s:?} vs {s0:?})"
                                );
                            }
                            data.extend_from_slice(d);
                        }
                        HostTensor::F32(..) => {
                            bail!("stack: dtype mismatch (f32 among i32)")
                        }
                    }
                }
                Ok(HostTensor::I32(shape, data))
            }
        }
    }
}

// ------------------------------------------------------------------ arena

#[derive(Debug)]
enum Scratch {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Scratch {
    fn capacity(&self) -> usize {
        match self {
            Scratch::F32(v) => v.capacity(),
            Scratch::I32(v) => v.capacity(),
        }
    }

    fn ptr(&self) -> usize {
        match self {
            Scratch::F32(v) => v.as_ptr() as usize,
            Scratch::I32(v) => v.as_ptr() as usize,
        }
    }
}

/// Reusable scratch memory for stacked-minibatch assembly.
///
/// One slot per stacked model input: `stack_into(slot, parts)` writes the
/// K per-step tensors contiguously into the slot's preallocated buffer
/// (clearing, never shrinking), so the steady-state chunk path performs
/// zero stacking allocations after the first chunk. Invalidation: a slot
/// is overwritten by the next `stack_into` on it — callers must consume
/// (convert to a `Literal`) before restacking the same slot.
#[derive(Debug, Default)]
pub struct LiteralArena {
    slots: Vec<Option<Scratch>>,
}

impl LiteralArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stack `parts` (same shape + dtype) along a new leading axis into
    /// slot scratch memory; returns the stacked dims `[K, shape...]`.
    pub fn stack_into(
        &mut self,
        slot: usize,
        parts: &[&HostTensor],
    ) -> Result<Vec<i64>> {
        let first = *parts.first().context("stack: empty input")?;
        if self.slots.len() <= slot {
            self.slots.resize_with(slot + 1, || None);
        }
        let mut dims: Vec<i64> = Vec::with_capacity(first.shape().len() + 1);
        dims.push(parts.len() as i64);
        dims.extend(first.shape().iter().map(|&d| d as i64));
        match first {
            HostTensor::F32(s0, _) => {
                let buf = self.f32_buf(slot);
                buf.clear();
                for &t in parts {
                    match t {
                        HostTensor::F32(s, d) => {
                            if s != s0 {
                                bail!(
                                    "stack: shape mismatch ({s:?} vs {s0:?})"
                                );
                            }
                            buf.extend_from_slice(d);
                        }
                        HostTensor::I32(..) => {
                            bail!("stack: dtype mismatch (i32 among f32)")
                        }
                    }
                }
            }
            HostTensor::I32(s0, _) => {
                let buf = self.i32_buf(slot);
                buf.clear();
                for &t in parts {
                    match t {
                        HostTensor::I32(s, d) => {
                            if s != s0 {
                                bail!(
                                    "stack: shape mismatch ({s:?} vs {s0:?})"
                                );
                            }
                            buf.extend_from_slice(d);
                        }
                        HostTensor::F32(..) => {
                            bail!("stack: dtype mismatch (f32 among i32)")
                        }
                    }
                }
            }
        }
        Ok(dims)
    }

    /// Stack into slot scratch and build the device literal.
    pub fn stack_literal(
        &mut self,
        slot: usize,
        parts: &[&HostTensor],
    ) -> Result<Literal> {
        let dims = self.stack_into(slot, parts)?;
        match self.slots[slot].as_ref().unwrap() {
            Scratch::F32(v) => Ok(Literal::vec1(v.as_slice()).reshape(&dims)?),
            Scratch::I32(v) => Ok(Literal::vec1(v.as_slice()).reshape(&dims)?),
        }
    }

    fn f32_buf(&mut self, slot: usize) -> &mut Vec<f32> {
        if !matches!(self.slots[slot], Some(Scratch::F32(_))) {
            self.slots[slot] = Some(Scratch::F32(Vec::new()));
        }
        match self.slots[slot] {
            Some(Scratch::F32(ref mut v)) => v,
            _ => unreachable!(),
        }
    }

    fn i32_buf(&mut self, slot: usize) -> &mut Vec<i32> {
        if !matches!(self.slots[slot], Some(Scratch::I32(_))) {
            self.slots[slot] = Some(Scratch::I32(Vec::new()));
        }
        match self.slots[slot] {
            Some(Scratch::I32(ref mut v)) => v,
            _ => unreachable!(),
        }
    }

    /// Current capacity of a slot's scratch buffer (0 if unused).
    pub fn slot_capacity(&self, slot: usize) -> usize {
        match self.slots.get(slot) {
            Some(Some(s)) => s.capacity(),
            _ => 0,
        }
    }

    /// Address of a slot's scratch buffer — lets tests assert that
    /// consecutive chunks reuse the same allocation.
    pub fn slot_ptr(&self, slot: usize) -> usize {
        match self.slots.get(slot) {
            Some(Some(s)) => s.ptr(),
            _ => 0,
        }
    }

    /// f32 contents of a slot (None if unused or i32).
    pub fn slot_f32(&self, slot: usize) -> Option<&[f32]> {
        match self.slots.get(slot) {
            Some(Some(Scratch::F32(v))) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// i32 contents of a slot (None if unused or f32).
    pub fn slot_i32(&self, slot: usize) -> Option<&[i32]> {
        match self.slots.get(slot) {
            Some(Some(Scratch::I32(v))) => Some(v.as_slice()),
            _ => None,
        }
    }
}

// ------------------------------------------------------------- train state

/// A shaped flat f32 buffer kept on the host between executable calls.
#[derive(Clone, Debug, Default)]
pub struct HostVec {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostVec {
    /// Download a literal's contents once (used at init).
    pub fn from_literal(l: &Literal) -> Result<HostVec> {
        let shape = l.array_shape()?;
        if !matches!(shape.ty(), xla::ElementType::F32) {
            bail!("HostVec: expected f32 state, got {:?}", shape.ty());
        }
        Ok(HostVec { dims: shape.dims().to_vec(), data: l.to_vec::<f32>()? })
    }

    /// Replace contents from an executable output, keeping dims.
    pub fn refill(&mut self, l: &Literal) -> Result<()> {
        let v = l.to_vec::<f32>()?;
        if v.len() != self.data.len() {
            bail!("HostVec::refill: {} elems, expected {}", v.len(), self.data.len());
        }
        self.data = v;
        Ok(())
    }

    /// Upload: build the argument literal from the cached host buffer.
    pub fn to_literal(&self) -> Result<Literal> {
        Ok(Literal::vec1(self.data.as_slice()).reshape(&self.dims)?)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
}

/// Training state: flat parameter + optimizer-state vectors, cached as
/// host buffers between chunk calls (uploaded once per `advance`, no
/// `clone_literal` host roundtrips). Plain data, so it is `Send` and can
/// be checkpointed directly from `params.data` / `opt_state.data`.
pub struct TrainState {
    pub params: HostVec,
    pub opt_state: HostVec,
    /// Optimizer steps taken so far.
    pub step: usize,
}

/// A fully-loaded model with its four entry points.
pub struct LoadedModel {
    pub spec: ModelSpec,
    pub init: CompiledFn,
    pub train_chunk: CompiledFn,
    pub train_step: CompiledFn,
    pub eval: CompiledFn,
}

/// Per-chunk step results.
#[derive(Clone, Debug)]
pub struct ChunkResult {
    pub losses: Vec<f32>,
    pub metrics: Vec<f32>,
}

impl LoadedModel {
    /// Run the init artifact; returns fresh training state.
    pub fn init_state(&self, seed: i32) -> Result<TrainState> {
        let outs = self.init.call(&[scalar_i32(seed)])?;
        if outs.len() != 2 {
            bail!("init returned {} outputs, want 2", outs.len());
        }
        Ok(TrainState {
            params: HostVec::from_literal(&outs[0])?,
            opt_state: HostVec::from_literal(&outs[1])?,
            step: 0,
        })
    }

    /// Advance `k` optimizer steps (k = spec.chunk for the chunk artifact,
    /// 1 for the step artifact). `stacked` are the K-step minibatch
    /// tensors (with leading K axis for the chunk call), `shared` the
    /// per-chunk tensors (borrowed, so the trainer can cache them across
    /// chunks), `q_fwd`/`lr`/`seeds` the per-step vectors. State is
    /// uploaded once from the cached host buffers and refilled from the
    /// outputs — zero `clone_literal` roundtrips.
    #[allow(clippy::too_many_arguments)]
    pub fn advance(
        &self,
        state: &mut TrainState,
        k: usize,
        stacked: &[Literal],
        shared: &[Literal],
        q_fwd: &[f32],
        lr: &[f32],
        seeds: &[i32],
        q_bwd: f32,
    ) -> Result<ChunkResult> {
        if q_fwd.len() != k || lr.len() != k || seeds.len() != k {
            bail!(
                "advance(k={k}): vector lengths q={} lr={} seeds={}",
                q_fwd.len(),
                lr.len(),
                seeds.len()
            );
        }
        let exe = if k == self.spec.chunk {
            &self.train_chunk
        } else if k == 1 {
            &self.train_step
        } else {
            bail!("advance: k={k} (chunk={}, step=1 only)", self.spec.chunk)
        };

        let params = state.params.to_literal()?;
        let opt = state.opt_state.to_literal()?;
        let q_lit = lit_f32(&[k], q_fwd)?;
        let lr_lit = lit_f32(&[k], lr)?;
        let seed_lit = lit_i32(&[k], seeds)?;
        let qb_lit = scalar_f32(q_bwd);

        let mut args: Vec<&Literal> =
            Vec::with_capacity(stacked.len() + shared.len() + 6);
        args.push(&params);
        args.push(&opt);
        args.extend(stacked.iter());
        args.extend(shared.iter());
        args.push(&q_lit);
        args.push(&lr_lit);
        args.push(&seed_lit);
        args.push(&qb_lit);

        let outs = exe.call_refs(&args)?;
        if outs.len() != 4 {
            bail!("train returned {} outputs, want 4", outs.len());
        }
        state.params.refill(&outs[0])?;
        state.opt_state.refill(&outs[1])?;
        state.step += k;
        let losses = outs[2].to_vec::<f32>()?;
        let metrics = outs[3].to_vec::<f32>()?;
        Ok(ChunkResult { losses, metrics })
    }

    /// Evaluate on one batch (borrowed, cacheable by the caller);
    /// returns (loss, metric). Uploads params once — callers looping
    /// over several eval batches should upload once themselves and use
    /// `evaluate_prepared`.
    pub fn evaluate(
        &self,
        state: &TrainState,
        data: &[Literal],
    ) -> Result<(f32, f32)> {
        let params = state.params.to_literal()?;
        self.evaluate_prepared(&params, data)
    }

    /// Evaluate with an already-uploaded params literal, so a multi-batch
    /// evaluation pays the (large) params upload exactly once.
    pub fn evaluate_prepared(
        &self,
        params: &Literal,
        data: &[Literal],
    ) -> Result<(f32, f32)> {
        let mut args: Vec<&Literal> = Vec::with_capacity(data.len() + 1);
        args.push(params);
        args.extend(data.iter());
        let outs = self.eval.call_refs(&args)?;
        if outs.len() != 2 {
            bail!("eval returned {} outputs, want 2", outs.len());
        }
        let loss = outs[0].get_first_element::<f32>()?;
        let metric = outs[1].get_first_element::<f32>()?;
        Ok((loss, metric))
    }
}

/// The xla crate's Literal has no Clone; round-trip through host data.
/// Kept off the hot path — only the perf bench uses it now, to measure
/// the legacy state-clone cost against the HostVec upload path.
pub fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match shape.ty() {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>()?;
            Ok(Literal::vec1(&v).reshape(&dims)?)
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>()?;
            Ok(Literal::vec1(&v).reshape(&dims)?)
        }
        t => bail!("clone_literal: unsupported type {t:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32t(shape: &[usize], data: &[f32]) -> HostTensor {
        HostTensor::F32(shape.to_vec(), data.to_vec())
    }

    fn i32t(shape: &[usize], data: &[i32]) -> HostTensor {
        HostTensor::I32(shape.to_vec(), data.to_vec())
    }

    #[test]
    fn stack_empty_input_errors() {
        let err = HostTensor::stack(&[]).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn stack_shape_mismatch_errors() {
        let a = f32t(&[2], &[1.0, 2.0]);
        let b = f32t(&[3], &[1.0, 2.0, 3.0]);
        let err = HostTensor::stack(&[a, b]).unwrap_err().to_string();
        assert!(err.contains("shape mismatch"), "{err}");
    }

    #[test]
    fn stack_dtype_mismatch_errors() {
        let a = f32t(&[2], &[1.0, 2.0]);
        let b = i32t(&[2], &[1, 2]);
        let err = HostTensor::stack(&[a, b]).unwrap_err().to_string();
        assert!(err.contains("dtype mismatch"), "{err}");
    }

    #[test]
    fn stack_shapes_and_contents() {
        let a = f32t(&[2], &[1.0, 2.0]);
        let b = f32t(&[2], &[3.0, 4.0]);
        match HostTensor::stack(&[a, b]).unwrap() {
            HostTensor::F32(s, d) => {
                assert_eq!(s, vec![2, 2]);
                assert_eq!(d, vec![1.0, 2.0, 3.0, 4.0]);
            }
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn arena_error_paths_match_stack() {
        let mut arena = LiteralArena::new();
        let err = arena.stack_into(0, &[]).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");

        let a = f32t(&[2], &[1.0, 2.0]);
        let b = f32t(&[3], &[1.0, 2.0, 3.0]);
        let err = arena.stack_into(0, &[&a, &b]).unwrap_err().to_string();
        assert!(err.contains("shape mismatch"), "{err}");

        let c = i32t(&[2], &[1, 2]);
        let err = arena.stack_into(0, &[&a, &c]).unwrap_err().to_string();
        assert!(err.contains("dtype mismatch"), "{err}");
    }

    #[test]
    fn arena_reuses_allocation_across_chunks() {
        let mut arena = LiteralArena::new();
        let a = f32t(&[3], &[1.0, 2.0, 3.0]);
        let b = f32t(&[3], &[4.0, 5.0, 6.0]);

        // chunk 1
        let dims = arena.stack_into(0, &[&a, &b]).unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(
            arena.slot_f32(0).unwrap(),
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
        let cap = arena.slot_capacity(0);
        let ptr = arena.slot_ptr(0);
        assert!(cap >= 6);

        // chunk 2: same slot, new contents — same allocation
        let c = f32t(&[3], &[7.0, 8.0, 9.0]);
        let d = f32t(&[3], &[10.0, 11.0, 12.0]);
        arena.stack_into(0, &[&c, &d]).unwrap();
        assert_eq!(
            arena.slot_f32(0).unwrap(),
            &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]
        );
        assert_eq!(arena.slot_capacity(0), cap, "capacity must not change");
        assert_eq!(arena.slot_ptr(0), ptr, "buffer must be reused in place");
    }

    #[test]
    fn arena_slots_are_independent_and_dtype_switchable() {
        let mut arena = LiteralArena::new();
        let a = f32t(&[1], &[1.5]);
        let y = i32t(&[2], &[7, 8]);
        arena.stack_into(0, &[&a]).unwrap();
        arena.stack_into(1, &[&y]).unwrap();
        assert_eq!(arena.slot_f32(0).unwrap(), &[1.5]);
        assert_eq!(arena.slot_i32(1).unwrap(), &[7, 8]);
        assert_eq!(arena.slot_f32(1), None);
        // a slot can be retyped (drops the old scratch)
        arena.stack_into(0, &[&y]).unwrap();
        assert_eq!(arena.slot_i32(0).unwrap(), &[7, 8]);
        assert_eq!(arena.slot_f32(0), None);
    }

    #[test]
    fn arena_unused_slot_accessors() {
        let arena = LiteralArena::new();
        assert_eq!(arena.slot_capacity(3), 0);
        assert_eq!(arena.slot_ptr(3), 0);
        assert_eq!(arena.slot_f32(3), None);
        assert_eq!(arena.slot_i32(3), None);
    }
}

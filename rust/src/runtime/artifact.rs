//! Artifact manifest: the contract between python/compile/aot.py (which
//! writes it) and the Rust runtime (which loads models through it).
//!
//! The manifest makes the Rust side fully generic over models — shapes,
//! dtypes, optimizer-state sizes and GEMM FLOP counts all come from here,
//! so adding a model never touches Rust code.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of a data input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

/// One data input of a model (per optimizer step).
#[derive(Clone, Debug)]
pub struct DataInput {
    pub name: String,
    /// Shape per step (without the chunk axis).
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// Stacked inputs gain a leading [K] axis in the train-chunk artifact
    /// (a fresh minibatch per step); shared inputs (e.g. a full graph) are
    /// passed once per chunk.
    pub stacked: bool,
}

impl DataInput {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named parameter tensor (for checkpointing / inspection).
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Everything the runtime needs to know about one exported model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub param_count: usize,
    pub opt_state_count: usize,
    pub chunk: usize,
    pub optimizer: String,
    pub metric: String,
    /// Quantized-GEMM FLOPs in one forward pass over one training batch.
    pub q_gemm_flops_fwd: u64,
    /// Full-precision GEMM FLOPs (e.g. attention scores).
    pub fp_gemm_flops_fwd: u64,
    /// GNN aggregation GEMM FLOPs, quantized (Q-Agg). Dense-simulated in
    /// the artifact; BitOps rescale by graph density (sparse on real
    /// graphs).
    pub agg_q_gemm_flops_fwd: u64,
    /// GNN aggregation GEMM FLOPs, full precision (FP-Agg).
    pub agg_fp_gemm_flops_fwd: u64,
    pub data_inputs: Vec<DataInput>,
    pub params: Vec<ParamEntry>,
    /// HLO file paths, keyed by "init" / "train_chunk" / "train_step" /
    /// "eval".
    pub files: std::collections::BTreeMap<String, PathBuf>,
}

impl ModelSpec {
    pub fn stacked_inputs(&self) -> impl Iterator<Item = &DataInput> {
        self.data_inputs.iter().filter(|d| d.stacked)
    }

    pub fn shared_inputs(&self) -> impl Iterator<Item = &DataInput> {
        self.data_inputs.iter().filter(|d| !d.stacked)
    }

    fn from_json(v: &Json, dir: &Path) -> Result<ModelSpec> {
        let name = v.get("name")?.as_str()?.to_string();
        let mut files = std::collections::BTreeMap::new();
        for (k, f) in v.get("files")?.as_obj()? {
            files.insert(k.clone(), dir.join(f.as_str()?));
        }
        let data_inputs = v
            .get("data_inputs")?
            .as_arr()?
            .iter()
            .map(|d| {
                Ok(DataInput {
                    name: d.get("name")?.as_str()?.to_string(),
                    shape: d
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<_>>()?,
                    dtype: DType::parse(d.get("dtype")?.as_str()?)?,
                    stacked: d.get("stacked")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let params = v
            .get("param_specs")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|x| x.as_usize())
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelSpec {
            name,
            param_count: v.get("param_count")?.as_usize()?,
            opt_state_count: v.get("opt_state_count")?.as_usize()?,
            chunk: v.get("chunk")?.as_usize()?,
            optimizer: v.get("optimizer")?.as_str()?.to_string(),
            metric: v.get("metric")?.as_str()?.to_string(),
            q_gemm_flops_fwd: v.get("q_gemm_flops_fwd")?.as_f64()? as u64,
            fp_gemm_flops_fwd: v.get("fp_gemm_flops_fwd")?.as_f64()? as u64,
            agg_q_gemm_flops_fwd: v
                .opt("agg_q_gemm_flops_fwd")
                .map(|x| x.as_f64())
                .transpose()?
                .unwrap_or(0.0) as u64,
            agg_fp_gemm_flops_fwd: v
                .opt("agg_fp_gemm_flops_fwd")
                .map(|x| x.as_f64())
                .transpose()?
                .unwrap_or(0.0) as u64,
            data_inputs,
            params,
            files,
        })
    }

    /// Consistency checks tying the manifest to itself.
    pub fn validate(&self) -> Result<()> {
        let declared: usize = self
            .params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum();
        if declared != self.param_count {
            bail!(
                "{}: param_specs sum {declared} != param_count {}",
                self.name,
                self.param_count
            );
        }
        for tag in ["init", "train_chunk", "train_step", "eval"] {
            let f = self
                .files
                .get(tag)
                .with_context(|| format!("{}: missing artifact '{tag}'", self.name))?;
            if !f.exists() {
                bail!("{}: artifact file missing: {}", self.name, f.display());
            }
        }
        Ok(())
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub chunk: usize,
    pub models: std::collections::BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&src).context("manifest.json parse error")?;
        let mut models = std::collections::BTreeMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            models.insert(name.clone(), ModelSpec::from_json(mv, dir)?);
        }
        Ok(Manifest { chunk: v.get("chunk")?.as_usize()?, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

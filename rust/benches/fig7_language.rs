//! Fig 7 reproduction: language understanding — LSTM LM perplexity (Penn
//! Treebank stand-in) and transformer entailment accuracy (XNLI
//! stand-in) vs GBitOps, schedule suite × q_max ∈ {6, 8}, n = 2 cycles
//! (paper §4.4 short-horizon setting).
//!
//!   cargo bench --bench fig7_language
//!
//! Set CPT_RUN_DIR=runs to persist per-cell artifacts and resume a
//! killed run where it stopped.

use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    // LSTM LM panel (perplexity: lower is better)
    let mut spec = SweepSpec::new("lstm_lm");
    spec.trials = scale.trials();
    spec.steps = Some(scale.steps(160, 400));
    spec.cycles = Some(2);
    spec.apply_env_run_dir(&manifest)?;
    spec.log_run_dir();
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let rows = aggregate(&outs);
    let rep = SweepReport::new(
        "Fig 7 left (Penn Treebank stand-in): perplexity vs GBitOps",
        "perplexity",
        false,
    );
    rep.print(&rows);
    rep.write_csv_with_timing(&rows, timing, cpt::results_dir().join("fig7_lstm.csv"))?;

    // transformer classifier panel (accuracy)
    let mut spec = SweepSpec::new("transformer_cls");
    spec.trials = scale.trials();
    spec.steps = Some(scale.steps(120, 240));
    spec.cycles = Some(2);
    spec.apply_env_run_dir(&manifest)?;
    spec.log_run_dir();
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let rows = aggregate(&outs);
    let rep = SweepReport::new(
        "Fig 7 right (XNLI stand-in): accuracy vs GBitOps",
        "accuracy",
        true,
    );
    rep.print(&rows);
    rep.write_csv_with_timing(
        &rows,
        timing,
        cpt::results_dir().join("fig7_transformer.csv"),
    )?;

    println!("\nPaper shape: q_max=6 visibly degrades both tasks; at q_max=8 the");
    println!("schedules trade compute for metric along the usual correlation.");
    Ok(())
}

//! Fig 5 reproduction: FP-Agg vs Q-Agg validation-accuracy curves at
//! static q_t = q_max = 8, for the GCN (Arxiv stand-in) and GraphSAGE
//! (Products stand-in).
//!
//!   cargo bench --bench fig5_aggregation

use cpt::metrics::CsvWriter;
use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let steps = scale.steps(240, 480);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    let mut w = CsvWriter::new(&["family", "agg", "trial", "step", "val_acc"]);
    println!("=== Fig 5: FP-Agg vs Q-Agg validation curves (q_t = q_max = 8) ===\n");

    for (fam, pair) in [
        ("gcn", ["gcn_fpagg", "gcn_qagg"]),
        ("sage", ["sage_fpagg", "sage_qagg"]),
    ] {
        println!("{fam}:");
        let mut finals = Vec::new();
        for name in pair {
            let model = rt.load_model(manifest.model(name)?)?;
            let agg = if name.ends_with("fpagg") { "FP-Agg" } else { "Q-Agg" };
            let mut trial_finals = Vec::new();
            for trial in 0..scale.trials() {
                let out = cpt::coordinator::run_one(
                    &model, name, "STATIC", 8.0, trial, steps, 8,
                    (steps / 12).max(1), false,
                )?;
                for &(step, _l, m) in &out.history.evals {
                    w.row(&[
                        fam.to_string(),
                        agg.to_string(),
                        trial.to_string(),
                        step.to_string(),
                        format!("{m:.5}"),
                    ]);
                }
                trial_finals.push(out.metric);
            }
            let (m, s) = cpt::data::mean_std(&trial_finals);
            println!("  {agg:<8} final val acc {m:.4} ± {s:.4}");
            finals.push(m);
        }
        println!("  FP − Q = {:+.4}\n", finals[0] - finals[1]);
    }

    let path = cpt::results_dir().join("fig5_aggregation.csv");
    w.write_to(&path)?;
    println!("wrote curves to {}", path.display());
    println!("\nPaper shape: slight but consistent FP-Agg advantage on the");
    println!("Arxiv-like graph; near-parity on the Products-like graph");
    println!("(sampled aggregation truncates the sum — footnote 4).");
    Ok(())
}

//! Fig 4 reproduction: object detection (PascalVOC stand-in) — mAP-lite
//! vs training GBitOps, schedule suite × q_max ∈ {6, 8}.
//!
//!   cargo bench --bench fig4_detection
//!
//! Set CPT_RUN_DIR=runs to persist per-cell artifacts and resume a
//! killed run where it stopped.

use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    let mut spec = SweepSpec::new("detector");
    spec.trials = scale.trials();
    spec.steps = Some(scale.steps(192, 256));
    spec.verbose = true;
    spec.apply_env_run_dir(&manifest)?;
    spec.log_run_dir();
    let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
    let rows = aggregate(&outs);
    let rep = SweepReport::new(
        "Fig 4 (PascalVOC stand-in): mAP-lite vs GBitOps",
        "mAP-lite",
        true,
    );
    rep.print(&rows);
    rep.write_csv_with_timing(
        &rows,
        timing,
        cpt::results_dir().join("fig4_detection.csv"),
    )?;

    println!("\nPaper shape: q_max=6 clearly deteriorates both baseline and CPT;");
    println!("at q_max=8 all CPT variants match/exceed STATIC at lower cost.");
    Ok(())
}

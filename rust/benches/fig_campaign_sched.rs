//! §Perf: campaign scheduling — sequential members vs the global worker
//! pool with per-worker compiled-executable caching.
//!
//! Two comparisons, each on real PJRT training (needs `make artifacts`):
//!   * a 2-member campaign sharing one model (the Fig 3/6/7 shape: two
//!     panels over the same network), run sequentially and then through
//!     the global scheduler with 2 workers — wall clock plus the compile
//!     count the executable cache saves (the acceptance bar: strictly
//!     fewer than members × workers compiles);
//!   * a single-member campaign both ways with one worker — the
//!     no-regression comparison for plain sweeps (recorded in the JSON
//!     and warned about loudly on a large gap; not a hard gate, because
//!     wall-clock asserts flake on loaded machines);
//!   * the shared campaign twice more with fresh pools over one
//!     persistent CPT_AOT_CACHE dir — cold-vs-warm wall clock and
//!     compile counts (warm must be 0 when the backend can serialize
//!     executables; otherwise the numbers document the inert fallback);
//!   * the serve shape: two distinct shared-model campaigns through one
//!     persistent worker pool (`run_campaign_pooled`, as the daemon
//!     wires it) vs each job paying for its own fresh pool — per-job
//!     wall clock, the cross-job compile count (hard gate: the second
//!     job compiles nothing), and both jobs concurrently in flight.
//!
//! Emits BENCH_campaign_sched.json (override with CPT_BENCH_JSON /
//! --json). The bench is already smoke-sized (tiny mlp sweeps), so it
//! has no separate --smoke mode.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use cpt::coordinator::campaign::{
    run_campaign_pooled, CampaignMember, CampaignRunOpts, CampaignRunResult,
    SchedulerKind,
};
use cpt::coordinator::{exec, pool, store};
use cpt::prelude::*;
use cpt::util::json::{num, obj, s, Json};

fn member(name: &str, schedules: &[&str], steps: usize) -> CampaignMember {
    let mut sp = SweepSpec::new("mlp");
    sp.schedules = schedules.iter().map(|x| x.to_string()).collect();
    sp.q_maxes = vec![8.0];
    sp.trials = 1;
    sp.steps = Some(steps);
    CampaignMember { name: name.into(), spec: sp, jobs: None }
}

fn run(
    manifest: &Manifest,
    plan: &CampaignPlan,
    root: &Path,
    jobs: usize,
    scheduler: SchedulerKind,
) -> Result<(CampaignRunResult, f64)> {
    let opts = CampaignRunOpts {
        root: root.to_path_buf(),
        shard: ShardId::single(),
        jobs,
        resume: false,
        verbose: false,
        scheduler,
    };
    let t0 = Instant::now();
    let result = run_campaign(manifest, plan, &opts)?;
    Ok((result, t0.elapsed().as_secs_f64()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var("CPT_BENCH_JSON").ok())
        .unwrap_or_else(|| "BENCH_campaign_sched.json".to_string());

    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let tmp = std::env::temp_dir().join("cpt_bench_campaign_sched");
    std::fs::remove_dir_all(&tmp).ok();

    println!("=== §Perf: campaign scheduling (sequential vs global pool) ===\n");

    // --- shared-model campaign: 2 members, 1 model, 2 workers ---------
    let members = 2usize;
    let workers = 2usize;
    let cspec = CampaignSpec {
        name: "bench-shared".into(),
        run_dir: None,
        members: vec![
            member("a", &["CR", "RR", "STATIC"], 16),
            member("b", &["CR", "ETH", "STATIC"], 16),
        ],
    };
    let plan = CampaignPlan::build(&cspec)?;
    let (_, seq_wall) = run(
        &manifest,
        &plan,
        &tmp.join("shared_seq"),
        workers,
        SchedulerKind::Sequential,
    )?;
    let (glob, glob_wall) = run(
        &manifest,
        &plan,
        &tmp.join("shared_glob"),
        workers,
        SchedulerKind::Global,
    )?;
    let sched = glob.scheduler.clone().expect("global scheduler stats");
    let compiles = sched.total_compiles();
    let budget = members * workers;
    println!(
        "shared-model campaign ({members} members x {workers} workers):"
    );
    println!("  sequential: {seq_wall:.2}s");
    println!(
        "  global:     {glob_wall:.2}s, {compiles} compile(s) \
         (cache budget without sharing: {budget})"
    );
    let cache_ok = compiles < budget;
    println!(
        "  executable cache: {} (compiles {} < members x workers {})",
        if cache_ok { "OK" } else { "FAILED" },
        compiles,
        budget
    );

    // --- single-member campaign, 1 worker: no-regression guard --------
    let single = CampaignSpec {
        name: "bench-single".into(),
        run_dir: None,
        members: vec![member("only", &["CR", "RR", "STATIC"], 16)],
    };
    let splan = CampaignPlan::build(&single)?;
    let (_, single_seq) = run(
        &manifest,
        &splan,
        &tmp.join("single_seq"),
        1,
        SchedulerKind::Sequential,
    )?;
    let (_, single_glob) = run(
        &manifest,
        &splan,
        &tmp.join("single_glob"),
        1,
        SchedulerKind::Global,
    )?;
    println!(
        "\nsingle-member campaign (1 worker): sequential {single_seq:.2}s, \
         global {single_glob:.2}s"
    );
    if single_glob > 1.5 * single_seq + 1.0 {
        eprintln!(
            "WARNING: global scheduler is much slower than sequential on a \
             single-member campaign ({single_glob:.2}s vs {single_seq:.2}s) \
             — queue/collector overhead may have regressed"
        );
    }

    // --- persistent AOT cache: cold vs warm pool over one dir ---------
    // Two fresh worker pools (each starting with empty in-memory caches,
    // the in-process stand-in for two processes) against one CPT_AOT_CACHE
    // dir. With a serialization-capable backend the warm pool must report
    // zero compiles; the vendored binding cannot serialize yet, so the
    // numbers then just document the graceful fallback (cold == warm).
    let aot_support = cpt::runtime::exec_serialization_support();
    let aot_dir = tmp.join("aotcache");
    std::env::set_var("CPT_AOT_CACHE", &aot_dir);
    let (aot_cold, aot_cold_wall) = run(
        &manifest,
        &plan,
        &tmp.join("aot_cold"),
        workers,
        SchedulerKind::Global,
    )?;
    let (aot_warm, aot_warm_wall) = run(
        &manifest,
        &plan,
        &tmp.join("aot_warm"),
        workers,
        SchedulerKind::Global,
    )?;
    std::env::remove_var("CPT_AOT_CACHE");
    let cold_sched = aot_cold.scheduler.expect("cold global scheduler stats");
    let warm_sched = aot_warm.scheduler.expect("warm global scheduler stats");
    let (cold_compiles, warm_compiles) =
        (cold_sched.total_compiles(), warm_sched.total_compiles());
    let warm_disk_hits = warm_sched.total_disk_hits();
    println!(
        "\npersistent AOT cache (fresh pools over one dir): \
         cold {aot_cold_wall:.2}s / {cold_compiles} compile(s), \
         warm {aot_warm_wall:.2}s / {warm_compiles} compile(s) \
         ({warm_disk_hits} disk hit(s))"
    );
    match aot_support {
        Ok(()) => {}
        Err(reason) => println!(
            "  (backend cannot serialize executables — {reason}; \
             the disk cache is inert and both pools compile)"
        ),
    }

    // --- serve: one persistent pool across jobs ----------------------
    // The daemon shape: two distinct shared-model campaigns through one
    // long-lived pool. Baseline is the pre-pool daemon — every job gets
    // a fresh pool, so every job pays the compiles again.
    let cspec2 = CampaignSpec {
        name: "bench-shared2".into(),
        run_dir: None,
        members: vec![
            member("c", &["CR", "RR", "STATIC"], 18),
            member("d", &["CR", "ETH", "STATIC"], 18),
        ],
    };
    let plan2 = CampaignPlan::build(&cspec2)?;
    let (jobs_a, jobs_a_wall) = run(
        &manifest,
        &plan,
        &tmp.join("serve_seq_a"),
        workers,
        SchedulerKind::Global,
    )?;
    let (jobs_b, jobs_b_wall) = run(
        &manifest,
        &plan2,
        &tmp.join("serve_seq_b"),
        workers,
        SchedulerKind::Global,
    )?;
    let seq_jobs_wall = jobs_a_wall + jobs_b_wall;
    let seq_jobs_compiles = jobs_a
        .scheduler
        .expect("job a scheduler stats")
        .total_compiles()
        + jobs_b.scheduler.expect("job b scheduler stats").total_compiles();

    let ms = manifest.model("mlp")?.clone();
    let mut fps = HashMap::new();
    fps.insert("mlp".to_string(), store::model_fingerprint(&ms)?);
    let mut specs_map = HashMap::new();
    specs_map.insert("mlp".to_string(), ms);
    let specs = Arc::new(exec::SpecRegistry::from_map(specs_map));
    let cache_cap = exec::exec_cache_cap()?;
    let factory: Arc<pool::WorkerFactory> = {
        let specs = specs.clone();
        Arc::new(move |_| {
            let r = exec::PjrtCellRunner::new(specs.clone(), cache_cap, None)?;
            Ok(Box::new(r) as Box<dyn exec::CellRunner>)
        })
    };
    let wpool = Arc::new(pool::WorkerPool::new(workers, "bench", factory));
    let popts = |root: PathBuf| CampaignRunOpts {
        root,
        shard: ShardId::single(),
        jobs: workers,
        resume: false,
        verbose: false,
        scheduler: SchedulerKind::Global,
    };
    let t0 = Instant::now();
    let pool_a = run_campaign_pooled(
        &plan,
        &popts(tmp.join("serve_pool_a")),
        &fps,
        None,
        &wpool,
    )?;
    let pool_a_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let pool_b = run_campaign_pooled(
        &plan2,
        &popts(tmp.join("serve_pool_b")),
        &fps,
        None,
        &wpool,
    )?;
    let pool_b_wall = t0.elapsed().as_secs_f64();
    let pool_jobs_wall = pool_a_wall + pool_b_wall;
    let pool_a_compiles = pool_a
        .scheduler
        .expect("pooled job a stats")
        .total_compiles();
    let cross_job_compiles = pool_b
        .scheduler
        .expect("pooled job b stats")
        .total_compiles();

    // both jobs in flight at once on the now-warm shared pool
    let t0 = Instant::now();
    std::thread::scope(|sc| -> Result<()> {
        let ja = sc.spawn(|| {
            run_campaign_pooled(
                &plan,
                &popts(tmp.join("serve_conc_a")),
                &fps,
                None,
                &wpool,
            )
        });
        let jb = sc.spawn(|| {
            run_campaign_pooled(
                &plan2,
                &popts(tmp.join("serve_conc_b")),
                &fps,
                None,
                &wpool,
            )
        });
        ja.join().expect("pooled job a thread")?;
        jb.join().expect("pooled job b thread")?;
        Ok(())
    })?;
    let concurrent_wall = t0.elapsed().as_secs_f64();
    wpool.join();
    println!(
        "\nserve pool (2 jobs sharing one model, {workers} workers): \
         fresh-pool-per-job {seq_jobs_wall:.2}s / {seq_jobs_compiles} \
         compile(s), persistent pool {pool_jobs_wall:.2}s \
         ({pool_a_compiles} + {cross_job_compiles} compile(s)), both \
         jobs concurrent {concurrent_wall:.2}s"
    );
    let cross_job_ok = cross_job_compiles == 0;
    println!(
        "  cross-job warm start: {} (second job compiled \
         {cross_job_compiles} time(s))",
        if cross_job_ok { "OK" } else { "FAILED" }
    );

    let worker_rows: Vec<Json> = sched
        .workers
        .iter()
        .map(|w| {
            obj(vec![
                ("worker", num(w.worker as f64)),
                ("compiles", num(w.compiles as f64)),
                ("compile_seconds", num(w.compile_seconds)),
                ("cells", num(w.cells as f64)),
            ])
        })
        .collect();
    let doc = obj(vec![
        ("bench", s("fig_campaign_sched")),
        ("version", num(3.0)),
        (
            "shared_model",
            obj(vec![
                ("members", num(members as f64)),
                ("workers", num(workers as f64)),
                ("sequential_wall_s", num(seq_wall)),
                ("global_wall_s", num(glob_wall)),
                ("global_compiles", num(compiles as f64)),
                ("compile_budget", num(budget as f64)),
                ("cache_effective", Json::Bool(cache_ok)),
                ("workers_detail", Json::Arr(worker_rows)),
            ]),
        ),
        (
            "single_member",
            obj(vec![
                ("sequential_wall_s", num(single_seq)),
                ("global_wall_s", num(single_glob)),
            ]),
        ),
        (
            "serve",
            obj(vec![
                ("workers", num(workers as f64)),
                ("sequential_jobs_wall_s", num(seq_jobs_wall)),
                ("sequential_jobs_compiles", num(seq_jobs_compiles as f64)),
                ("pooled_jobs_wall_s", num(pool_jobs_wall)),
                ("pooled_first_job_compiles", num(pool_a_compiles as f64)),
                ("cross_job_compiles", num(cross_job_compiles as f64)),
                ("cross_job_warm", Json::Bool(cross_job_ok)),
                ("concurrent_jobs_wall_s", num(concurrent_wall)),
            ]),
        ),
        (
            "aot",
            obj(vec![
                ("supported", Json::Bool(aot_support.is_ok())),
                ("reason", s(aot_support.err().unwrap_or(""))),
                ("cold_wall_s", num(aot_cold_wall)),
                ("warm_wall_s", num(aot_warm_wall)),
                ("cold_compiles", num(cold_compiles as f64)),
                ("warm_compiles", num(warm_compiles as f64)),
                ("warm_disk_hits", num(warm_disk_hits as f64)),
            ]),
        ),
    ]);
    std::fs::write(&json_path, doc.to_string_pretty())?;
    println!("\nwrote {json_path}");
    std::fs::remove_dir_all(&tmp).ok();

    let out: PathBuf = json_path.into();
    anyhow::ensure!(
        cache_ok,
        "global scheduler recompiled a shared model: {} compiles on a \
         {}-member x {}-worker shared-model campaign (see {})",
        compiles,
        members,
        workers,
        out.display()
    );
    anyhow::ensure!(
        cross_job_ok,
        "persistent pool recompiled a shared model across jobs: the \
         second job compiled {} time(s) (see {})",
        cross_job_compiles,
        out.display()
    );
    // hard gate only when the backend can actually serialize — otherwise
    // the disk cache is inert by design and warm == cold is correct
    if aot_support.is_ok() {
        anyhow::ensure!(
            warm_compiles == 0,
            "warm pool over a populated AOT cache still compiled \
             {warm_compiles} time(s) (see {})",
            out.display()
        );
    }
    Ok(())
}

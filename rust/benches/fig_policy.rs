//! §Policies: adaptive precision policies vs the best static CPT
//! schedules — the experiment the paper could not run, because its
//! schedules are fixed up front.
//!
//! On real PJRT training (needs `make artifacts`), one model (mlp):
//!   * a static reference sweep over a spread of suite schedules
//!     (Group I / II / III members + the STATIC baseline);
//!   * a `loss_plateau` policy sweep (MuPPET-style switching);
//!   * two `cost_governor` sweeps with targets bracketing the suite's
//!     cost range.
//!
//! Reported per row: metric, GBitOps, realized mean q/q_max, realized
//! relative cost. Two structural gates (training quality itself is not
//! gated — it flakes):
//!   * every adaptive row's realized cost must be < 1 (an adaptive run
//!     that costs more than static-q_max means the feedback loop is
//!     broken);
//!   * each governor's realized cost must land within tolerance of its
//!     target (the budget-steering contract, end-to-end through real
//!     training).
//!
//! Emits BENCH_policy.json (override with CPT_BENCH_JSON / --json).

use anyhow::Result;
use cpt::coordinator::campaign::set_policy;
use cpt::prelude::*;
use cpt::util::json::{num, obj, s, Json};

struct Row {
    label: String,
    q_max: f64,
    metric: f64,
    gbitops: f64,
    mean_q: f64,
    realized_cost: f64,
}

fn rows_of(outs: &[RunOutcome]) -> Vec<Row> {
    aggregate(outs)
        .into_iter()
        .map(|r| Row {
            label: r.schedule,
            q_max: r.q_max,
            metric: r.metric_mean,
            gbitops: r.gbitops,
            mean_q: r.mean_q,
            realized_cost: r.realized_cost,
        })
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var("CPT_BENCH_JSON").ok())
        .unwrap_or_else(|| "BENCH_policy.json".to_string());
    let scale = cpt::bench_scale();
    let steps = scale.steps(48, 128);
    let trials = scale.trials();

    let manifest = Manifest::load(cpt::artifacts_dir())?;
    println!("=== §Policies: adaptive precision vs static schedules (mlp) ===\n");

    // --- static reference: one schedule per savings group + baseline ---
    let mut static_spec = SweepSpec::new("mlp");
    static_spec.schedules =
        vec!["RR".into(), "CR".into(), "ETH".into(), "STATIC".into()];
    static_spec.q_maxes = vec![8.0];
    static_spec.trials = trials;
    static_spec.steps = Some(steps);
    static_spec.apply_env_run_dir(&manifest)?;
    static_spec.log_run_dir();
    let static_outs = run_sweep(&manifest, &static_spec)?;
    let static_rows = rows_of(&static_outs);

    // --- adaptive sweeps ----------------------------------------------
    let policies = [
        "loss_plateau:patience=2,ema=0.5".to_string(),
        "cost_governor:target=0.55".to_string(),
        "cost_governor:target=0.75".to_string(),
    ];
    let mut adaptive_rows: Vec<(String, Vec<Row>)> = Vec::new();
    for p in &policies {
        let mut spec = SweepSpec::new("mlp");
        set_policy(&mut spec, PolicySpec::parse(p)?, false)?;
        spec.q_maxes = vec![8.0];
        spec.trials = trials;
        spec.steps = Some(steps);
        spec.apply_env_run_dir(&manifest)?;
        spec.log_run_dir();
        let outs = run_sweep(&manifest, &spec)?;
        adaptive_rows.push((p.clone(), rows_of(&outs)));
    }

    // --- report --------------------------------------------------------
    println!(
        "{:<28} {:>8} {:>10} {:>8} {:>10}",
        "schedule/policy", "metric", "GBitOps", "mean_q", "rel.cost"
    );
    let print_rows = |rows: &[Row]| {
        for r in rows {
            println!(
                "{:<28} {:>8.4} {:>10.4} {:>8.4} {:>10.4}",
                format!("{} (q{})", r.label, r.q_max),
                r.metric,
                r.gbitops,
                r.mean_q,
                r.realized_cost
            );
        }
    };
    print_rows(&static_rows);
    for (p, rows) in &adaptive_rows {
        println!("-- {p}");
        print_rows(rows);
    }
    let best_static = static_rows
        .iter()
        .filter(|r| r.label != "STATIC" && !r.metric.is_nan())
        .max_by(|a, b| a.metric.total_cmp(&b.metric))
        .unwrap_or(&static_rows[0]);
    println!(
        "\nbest static schedule: {} metric {:.4} at relative cost {:.4}",
        best_static.label, best_static.metric, best_static.realized_cost
    );

    // --- gates ---------------------------------------------------------
    let mut failures: Vec<String> = Vec::new();
    for (p, rows) in &adaptive_rows {
        for r in rows {
            if r.realized_cost.is_nan() || r.realized_cost >= 1.0 {
                failures.push(format!(
                    "{p}: realized cost {:.4} >= 1 (adaptive run costs \
                     more than static q_max)",
                    r.realized_cost
                ));
            }
        }
        if let Some(target) = p
            .strip_prefix("cost_governor:target=")
            .and_then(|t| t.parse::<f64>().ok())
        {
            // one-step granularity on short runs plus float slack
            let tol = 1.0 / steps as f64 + 0.03;
            for r in rows {
                if (r.realized_cost - target).abs() > tol {
                    failures.push(format!(
                        "{p}: realized cost {:.4} missed target {target} \
                         (tol {tol:.4})",
                        r.realized_cost
                    ));
                }
            }
        }
    }

    let row_json = |r: &Row| {
        obj(vec![
            ("label", s(&r.label)),
            ("q_max", num(r.q_max)),
            ("metric", num(r.metric)),
            ("gbitops", num(r.gbitops)),
            ("mean_q", num(r.mean_q)),
            ("realized_cost", num(r.realized_cost)),
        ])
    };
    let doc = obj(vec![
        ("bench", s("fig_policy")),
        ("version", num(1.0)),
        ("model", s("mlp")),
        ("steps", num(steps as f64)),
        ("trials", num(trials as f64)),
        (
            "static_rows",
            Json::Arr(static_rows.iter().map(row_json).collect()),
        ),
        (
            "adaptive",
            Json::Arr(
                adaptive_rows
                    .iter()
                    .map(|(p, rows)| {
                        obj(vec![
                            ("policy", s(p)),
                            (
                                "rows",
                                Json::Arr(
                                    rows.iter().map(row_json).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "best_static",
            obj(vec![
                ("label", s(&best_static.label)),
                ("metric", num(best_static.metric)),
                ("realized_cost", num(best_static.realized_cost)),
            ]),
        ),
        ("gates_passed", Json::Bool(failures.is_empty())),
    ]);
    std::fs::write(&json_path, doc.to_string_pretty())?;
    println!("\nwrote {json_path}");

    anyhow::ensure!(
        failures.is_empty(),
        "policy gates failed:\n  {}",
        failures.join("\n  ")
    );
    Ok(())
}

//! Fig 2 reproduction: the schedule suite's S(t)/q_t series + the
//! group/cost table. Analytic (no PJRT); also sweeps cycle counts.
//!
//!   cargo bench --bench fig2_schedules

use cpt::metrics::CsvWriter;
use cpt::schedule::{group_of, relative_cost, suite};

fn main() -> anyhow::Result<()> {
    let total = 800;
    let (q_min, q_max) = (3.0, 8.0);

    println!("=== Fig 2: CPT schedule suite (T={total}, q in [{q_min},{q_max}]) ===\n");
    println!(
        "{:<9} {:<10} {:>8} {:>12} {:>10}",
        "schedule", "group", "cycles", "mean q/qmax", "rel. cost"
    );
    let mut w = CsvWriter::new(&["schedule", "n", "t", "s_t", "q_t"]);
    for n in [2usize, 4, 8] {
        for name in suite::suite_names() {
            let s = suite::by_name(name, q_min, q_max, total, n)?;
            if n == 8 {
                println!(
                    "{:<9} {:<10} {:>8} {:>12.3} {:>10.3}",
                    name,
                    group_of(name).label(),
                    n,
                    s.mean_relative_precision(total),
                    relative_cost(&s, q_max, total)
                );
            }
            for t in 0..total {
                w.row(&[
                    name.to_string(),
                    n.to_string(),
                    t.to_string(),
                    format!("{:.5}", s.value_at(t)),
                    s.q_at(t).to_string(),
                ]);
            }
        }
    }
    let path = cpt::results_dir().join("fig2_schedules.csv");
    w.write_to(&path)?;
    println!("\nwrote series (n = 2, 4, 8) to {}", path.display());

    // invariant check printed for the record: group cost ordering
    let cost = |n: &str| {
        relative_cost(&suite::by_name(n, q_min, q_max, total, 8).unwrap(), q_max, total)
    };
    let large = (cost("RR") + cost("RTH")) / 2.0;
    let medium = ["LR", "LT", "CR", "CT", "RTV", "ETV"]
        .iter()
        .map(|n| cost(n))
        .sum::<f64>()
        / 6.0;
    let small = (cost("ER") + cost("ETH")) / 2.0;
    println!(
        "\ngroup mean relative cost: Large {large:.3} < Medium {medium:.3} < Small {small:.3} ({})",
        if large < medium && medium < small { "OK" } else { "VIOLATED" }
    );
    Ok(())
}

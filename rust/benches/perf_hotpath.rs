//! §Perf microbenchmarks for the L3 hot path (criterion is unavailable
//! offline; this is a handmade timing harness with warmup + repeated
//! samples + mean/min reporting).
//!
//! Measures, per model:
//!   * chunk-call latency (K optimizer steps in one PJRT call),
//!   * K single-step calls (what the loop would cost without chunking),
//!   * the host-side overhead components: state clone (the PJRT shim's
//!     forced host roundtrip), batch generation, literal creation.
//!
//!   cargo bench --bench perf_hotpath

use std::time::Instant;

use cpt::prelude::*;
use cpt::runtime::clone_literal;

fn time<F: FnMut() -> anyhow::Result<()>>(
    reps: usize,
    mut f: F,
) -> anyhow::Result<(f64, f64)> {
    // warmup
    f()?;
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    Ok((mean, min))
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    println!("=== §Perf: L3 hot-path microbenchmarks (ms; mean/min of 5) ===\n");
    println!(
        "{:<16} {:>6} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "model", "K", "chunk(K)", "K x step(1)", "speedup",
        "state-clone", "batch-gen"
    );

    for name in ["mlp", "gcn_qagg", "lstm_lm", "transformer_lm"] {
        let spec = manifest.model(name)?;
        let model = rt.load_model(spec)?;
        let k = spec.chunk;
        let rec = recipe(name)?;
        let mut data = dataset_for(name, 1)?;

        // pre-build chunk inputs
        let build_inputs = |data: &mut Box<dyn Dataset>,
                            k: usize|
         -> anyhow::Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
            let mut per_input: Vec<Vec<HostTensor>> = Vec::new();
            for i in 0..k {
                let b = data.train_batch(i)?;
                if per_input.is_empty() {
                    per_input = b.into_iter().map(|t| vec![t]).collect();
                } else {
                    for (slot, t) in per_input.iter_mut().zip(b) {
                        slot.push(t);
                    }
                }
            }
            let stacked = per_input
                .iter()
                .map(|ts| HostTensor::stack(ts)?.to_literal())
                .collect::<anyhow::Result<Vec<_>>>()?;
            let shared = data
                .shared_inputs(0)?
                .iter()
                .map(|t| t.to_literal())
                .collect::<anyhow::Result<Vec<_>>>()?;
            Ok((stacked, shared))
        };

        let q = vec![8.0f32; k];
        let lr = vec![rec.base_lr; k];
        let seeds: Vec<i32> = (0..k as i32).collect();

        // chunk call
        let mut st = model.init_state(0)?;
        let (mean_chunk, _) = time(5, || {
            let (stacked, shared) = build_inputs(&mut data, k)?;
            model.advance(&mut st, k, stacked, shared, &q, &lr, &seeds, 8.0)?;
            Ok(())
        })?;

        // K single-step calls
        let mut st2 = model.init_state(0)?;
        let (mean_steps, _) = time(5, || {
            for i in 0..k {
                let (stacked, shared) = build_inputs(&mut data, 1)?;
                model.advance(
                    &mut st2,
                    1,
                    stacked,
                    shared,
                    &q[i..i + 1],
                    &lr[i..i + 1],
                    &seeds[i..i + 1],
                    8.0,
                )?;
            }
            Ok(())
        })?;

        // state clone cost (the forced host roundtrip component)
        let (mean_clone, _) = time(5, || {
            let _p = clone_literal(&st.params)?;
            let _o = clone_literal(&st.opt_state)?;
            Ok(())
        })?;

        // batch generation cost
        let (mean_gen, _) = time(5, || {
            let _ = build_inputs(&mut data, k)?;
            Ok(())
        })?;

        println!(
            "{:<16} {:>6} {:>14.2} {:>14.2} {:>11.2}x {:>12.3} {:>12.2}",
            name,
            k,
            mean_chunk,
            mean_steps,
            mean_steps / mean_chunk,
            mean_clone,
            mean_gen
        );
    }

    println!(
        "\nInterpretation: chunking amortizes the per-call host roundtrip\n\
         (params + opt state cloned in, tuple result copied out) over K\n\
         steps — the 'speedup' column is the §Perf before/after for L3."
    );
    Ok(())
}

//! §Perf microbenchmarks for the L3 hot path (criterion is unavailable
//! offline; this is a handmade timing harness with warmup + repeated
//! samples + mean/min reporting).
//!
//! Measures, per model:
//!   * chunk-call latency (K optimizer steps in one PJRT call) on the
//!     zero-roundtrip path (HostVec state upload, arena-stacked inputs),
//!   * K single-step calls (what the loop would cost without chunking),
//!   * the host-side overhead components, old path vs new path:
//!       - state-clone (legacy `clone_literal` roundtrip, eliminated
//!         from `Trainer::run`) vs state-upload (`HostVec::to_literal`),
//!       - batch-gen via fresh `Vec<Vec<HostTensor>>` stacking vs the
//!         reusable `LiteralArena`.
//!
//! Emits a machine-readable BENCH_perf_hotpath.json (override the path
//! with CPT_BENCH_JSON) so the perf trajectory is tracked across PRs.
//!
//!   cargo bench --bench perf_hotpath             # 5 reps, 4 models
//!   cargo bench --bench perf_hotpath -- --smoke  # 1 rep, mlp only
//!
//!   cargo bench --bench perf_hotpath -- --json out.json

use std::time::Instant;

use cpt::prelude::*;
use cpt::runtime::clone_literal;
use cpt::util::json::{num, obj, s, Json};

fn time<F: FnMut() -> anyhow::Result<()>>(
    reps: usize,
    mut f: F,
) -> anyhow::Result<(f64, f64)> {
    // warmup
    f()?;
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    Ok((mean, min))
}

/// Legacy input assembly: fresh Vec<Vec<HostTensor>> regroup + stack +
/// literal per chunk (the pre-arena path, kept as the baseline).
fn build_inputs_legacy(
    data: &mut Box<dyn Dataset>,
    k: usize,
) -> anyhow::Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
    let mut per_input: Vec<Vec<HostTensor>> = Vec::new();
    for i in 0..k {
        let b = data.train_batch(i)?;
        if per_input.is_empty() {
            per_input = b.into_iter().map(|t| vec![t]).collect();
        } else {
            for (slot, t) in per_input.iter_mut().zip(b) {
                slot.push(t);
            }
        }
    }
    let stacked = per_input
        .iter()
        .map(|ts| HostTensor::stack(ts)?.to_literal())
        .collect::<anyhow::Result<Vec<_>>>()?;
    let shared = data
        .shared_inputs(0)?
        .iter()
        .map(|t| t.to_literal())
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok((stacked, shared))
}

/// Arena input assembly: the trainer's steady-state path.
fn build_inputs_arena(
    data: &mut Box<dyn Dataset>,
    arena: &mut LiteralArena,
    rows: &mut Vec<Vec<HostTensor>>,
    k: usize,
) -> anyhow::Result<(Vec<xla::Literal>, Vec<xla::Literal>)> {
    rows.clear();
    for i in 0..k {
        rows.push(data.train_batch(i)?);
    }
    let n_slots = rows.first().map(|r| r.len()).unwrap_or(0);
    let mut stacked = Vec::with_capacity(n_slots);
    for j in 0..n_slots {
        let parts: Vec<&HostTensor> = rows.iter().map(|r| &r[j]).collect();
        stacked.push(arena.stack_literal(j, &parts)?);
    }
    let shared = data
        .shared_inputs(0)?
        .iter()
        .map(|t| t.to_literal())
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok((stacked, shared))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || std::env::var("CPT_SMOKE")
            .is_ok_and(|v| v == "1" || v == "true");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| std::env::var("CPT_BENCH_JSON").ok())
        .unwrap_or_else(|| "BENCH_perf_hotpath.json".to_string());
    let reps = if smoke { 1 } else { 5 };
    let models: &[&str] = if smoke {
        &["mlp"]
    } else {
        &["mlp", "gcn_qagg", "lstm_lm", "transformer_lm"]
    };

    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    println!(
        "=== §Perf: L3 hot-path microbenchmarks (ms; mean of {reps}) ===\n"
    );
    println!(
        "{:<16} {:>4} {:>12} {:>12} {:>9} {:>12} {:>12} {:>12} {:>12}",
        "model",
        "K",
        "chunk(K)",
        "K x step(1)",
        "speedup",
        "clone(old)",
        "upload(new)",
        "gen(old)",
        "gen(arena)"
    );

    let mut model_rows: Vec<(String, Json)> = Vec::new();

    for &name in models {
        let spec = manifest.model(name)?;
        let model = rt.load_model(spec)?;
        let k = spec.chunk;
        let rec = recipe(name)?;
        let mut data = dataset_for(name, 1)?;
        let mut arena = LiteralArena::new();
        let mut rows: Vec<Vec<HostTensor>> = Vec::new();

        let q = vec![8.0f32; k];
        let lr = vec![rec.base_lr; k];
        let seeds: Vec<i32> = (0..k as i32).collect();

        // chunk call on the new path (arena inputs, HostVec state)
        let mut st = model.init_state(0)?;
        let (mean_chunk, min_chunk) = time(reps, || {
            let (stacked, shared) =
                build_inputs_arena(&mut data, &mut arena, &mut rows, k)?;
            model.advance(&mut st, k, &stacked, &shared, &q, &lr, &seeds, 8.0)?;
            Ok(())
        })?;

        // K single-step calls
        let mut st2 = model.init_state(0)?;
        let (mean_steps, _) = time(reps, || {
            for i in 0..k {
                let (stacked, shared) =
                    build_inputs_arena(&mut data, &mut arena, &mut rows, 1)?;
                model.advance(
                    &mut st2,
                    1,
                    &stacked,
                    &shared,
                    &q[i..i + 1],
                    &lr[i..i + 1],
                    &seeds[i..i + 1],
                    8.0,
                )?;
            }
            Ok(())
        })?;

        // legacy state-clone cost (the roundtrip `Trainer::run` used to
        // pay per chunk, now eliminated): clone an uploaded literal
        let params_lit = st.params.to_literal()?;
        let opt_lit = st.opt_state.to_literal()?;
        let (mean_clone, _) = time(reps, || {
            let _p = clone_literal(&params_lit)?;
            let _o = clone_literal(&opt_lit)?;
            Ok(())
        })?;

        // new state-upload cost (HostVec -> literal, once per advance)
        let (mean_upload, _) = time(reps, || {
            let _p = st.params.to_literal()?;
            let _o = st.opt_state.to_literal()?;
            Ok(())
        })?;

        // batch generation: legacy fresh-alloc stacking vs arena reuse
        let (mean_gen_legacy, _) =
            time(reps, || build_inputs_legacy(&mut data, k).map(|_| ()))?;
        let (mean_gen_arena, _) = time(reps, || {
            build_inputs_arena(&mut data, &mut arena, &mut rows, k).map(|_| ())
        })?;

        println!(
            "{:<16} {:>4} {:>12.2} {:>12.2} {:>8.2}x {:>12.3} {:>12.3} {:>12.2} {:>12.2}",
            name,
            k,
            mean_chunk,
            mean_steps,
            mean_steps / mean_chunk,
            mean_clone,
            mean_upload,
            mean_gen_legacy,
            mean_gen_arena
        );

        model_rows.push((
            name.to_string(),
            obj(vec![
                ("k", num(k as f64)),
                ("chunk_ms_mean", num(mean_chunk)),
                ("chunk_ms_min", num(min_chunk)),
                ("ksteps_ms_mean", num(mean_steps)),
                ("chunk_speedup", num(mean_steps / mean_chunk)),
                ("state_clone_legacy_ms", num(mean_clone)),
                ("state_upload_ms", num(mean_upload)),
                ("batchgen_legacy_ms", num(mean_gen_legacy)),
                ("batchgen_arena_ms", num(mean_gen_arena)),
            ]),
        ));
    }

    let doc = obj(vec![
        ("bench", s("perf_hotpath")),
        ("version", num(2.0)),
        ("reps", num(reps as f64)),
        ("smoke", Json::Bool(smoke)),
        (
            "models",
            Json::Obj(model_rows.into_iter().collect()),
        ),
    ]);
    std::fs::write(&json_path, doc.to_string_pretty())?;
    println!("\nwrote {json_path}");

    println!(
        "\nInterpretation: 'clone(old)' is the per-chunk host roundtrip the\n\
         trainer used to pay per state tensor pair; the new path pays only\n\
         'upload(new)' (HostVec -> literal, once per advance) and zero\n\
         clone_literal calls. 'gen(arena)' vs 'gen(old)' shows the stacked-\n\
         minibatch scratch reuse. The 'speedup' column is chunking's\n\
         amortization of the per-call PJRT overhead over K steps."
    );
    Ok(())
}

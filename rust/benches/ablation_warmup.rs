//! Ablation: the §5 remedy — "this problem can be solved by simply
//! delaying the use of low precision until later during the training
//! process". Composes a full-precision warmup over the aggressive RR
//! schedule (q_min = 2, where plain RR is damaged by the critical
//! period) and sweeps the warmup length.
//!
//!   cargo bench --bench ablation_warmup

use cpt::metrics::CsvWriter;
use cpt::prelude::*;
use cpt::schedule::{suite, Schedule};

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let steps = scale.steps(240, 480);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let model = rt.load_model(manifest.model("gcn_qagg")?)?;
    let rec = recipe("gcn_qagg")?;

    let run = |schedule: Schedule, trial: usize| -> anyhow::Result<(f32, f64)> {
        let mut data = dataset_for("gcn_qagg", 1000 + trial as u64)?;
        let cfg = TrainConfig {
            total_steps: steps,
            q_bwd: 8.0,
            eval_every: 0,
            seed: 7 * (trial as i32 + 1),
            log_every: 4,
            verbose: false,
        };
        let mut t = Trainer::new(
            &model, data.as_mut(), schedule, rec.lr_schedule(steps), cfg,
        );
        let h = t.run()?;
        Ok((h.final_eval_metric().unwrap_or(f32::NAN), h.gbitops))
    };

    let mut w = CsvWriter::new(&["warmup", "trial", "accuracy", "gbitops"]);
    println!(
        "=== Ablation: q_max warmup over aggressive RR (q in [2,8], {steps} steps) ===\n"
    );
    println!("{:<12} {:>12} {:>12}", "warmup steps", "accuracy", "GBitOps");

    // baseline: static q_max
    {
        let mut accs = Vec::new();
        let mut gb = 0.0;
        for trial in 0..scale.trials() {
            let (a, g) = run(Schedule::static_q(8.0), trial)?;
            accs.push(a as f64);
            gb = g;
            w.row(&["STATIC".into(), trial.to_string(), format!("{a:.5}"),
                    format!("{g:.5}")]);
        }
        let (m, s) = cpt::data::mean_std(&accs);
        println!("{:<12} {m:>9.4} ± {s:.4} {gb:>9.4}", "STATIC");
    }

    for frac in [0.0, 0.125, 0.25, 0.5] {
        let warm = (frac * steps as f64) as usize;
        let mut accs = Vec::new();
        let mut gb = 0.0;
        for trial in 0..scale.trials() {
            let inner =
                suite::by_name("RR", 2.0, 8.0, steps - warm, 8)?;
            let sched = if warm == 0 {
                inner
            } else {
                Schedule::with_warmup(8.0, warm, inner)
            };
            let (a, g) = run(sched, trial)?;
            accs.push(a as f64);
            gb = g;
            w.row(&[warm.to_string(), trial.to_string(), format!("{a:.5}"),
                    format!("{g:.5}")]);
        }
        let (m, s) = cpt::data::mean_std(&accs);
        println!("{:<12} {m:>9.4} ± {s:.4} {gb:>9.4}", warm);
    }

    let path = cpt::results_dir().join("ablation_warmup.csv");
    w.write_to(&path)?;
    println!("\nwrote {}", path.display());
    println!("\nExpected (§5): warmup covering the critical period recovers the");
    println!("accuracy that aggressive quantization loses, at intermediate cost.");
    Ok(())
}

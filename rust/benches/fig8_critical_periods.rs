//! Fig 8 reproduction: critical learning periods in GNN training.
//!
//! Left panel: train at the crippling precision (q_low = 2 on this
//! substrate — the paper's q_min = 3 was likewise chosen as the edge
//! where training stops progressing; our range test puts the 512-node
//! SBM GCN's edge at 2) for the first R steps, then q_max = 8
//! for the full normal duration — final accuracy vs R (plus the normal-
//! training accuracy curve for reference).
//! Right panel: a fixed-length q_min window placed at different offsets
//! ("probing") — final accuracy vs window position.
//!
//!   cargo bench --bench fig8_critical_periods

use cpt::metrics::CsvWriter;
use cpt::prelude::*;
use cpt::schedule::Schedule;

fn run(
    model: &LoadedModel,
    schedule: Schedule,
    total: usize,
    trial: usize,
) -> anyhow::Result<f32> {
    let mut data = dataset_for("gcn_qagg", 42 + trial as u64)?;
    let rec = recipe("gcn_qagg")?;
    let cfg = TrainConfig {
        total_steps: total,
        q_bwd: 8.0,
        eval_every: 0,
        seed: 11 + trial as i32,
        log_every: 8,
        verbose: false,
    };
    let mut t = Trainer::new(
        model,
        data.as_mut(),
        schedule,
        rec.lr_schedule(total),
        cfg,
    );
    Ok(t.run()?.final_eval_metric().unwrap_or(f32::NAN))
}

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let trials = scale.trials();
    // "normal duration" N; deficit-R runs train R + N steps total
    let n_steps = scale.steps(240, 480);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let model = rt.load_model(manifest.model("gcn_qagg")?)?;

    let mut w = CsvWriter::new(&["panel", "x", "trial", "accuracy"]);

    // ---- left panel: deficit for the first R steps, then normal training
    println!("=== Fig 8 left: initial deficit of R steps (then {n_steps} normal steps) ===");
    println!("{:>6} {:>12}", "R", "accuracy");
    for frac in [0.0, 0.1, 0.2, 0.3, 0.5, 0.7, 1.0] {
        let r = (frac * n_steps as f64) as usize;
        let mut accs = Vec::new();
        for trial in 0..trials {
            let s = Schedule::deficit(2.0, 8.0, 0, r);
            let acc = run(&model, s, r + n_steps, trial)?;
            w.row(&[
                "deficit_R".into(),
                r.to_string(),
                trial.to_string(),
                format!("{acc:.5}"),
            ]);
            accs.push(acc as f64);
        }
        let (m, s) = cpt::data::mean_std(&accs);
        println!("{r:>6} {m:>12.4} ± {s:.4}");
    }

    // ---- reference: per-step accuracy of normal training (green curve)
    {
        let mut data = dataset_for("gcn_qagg", 42)?;
        let rec = recipe("gcn_qagg")?;
        let cfg = TrainConfig {
            total_steps: n_steps,
            q_bwd: 8.0,
            eval_every: (n_steps / 12).max(1),
            seed: 11,
            log_every: 8,
            verbose: false,
        };
        let mut t = Trainer::new(
            &model,
            data.as_mut(),
            Schedule::static_q(8.0),
            rec.lr_schedule(n_steps),
            cfg,
        );
        let h = t.run()?;
        for &(step, _l, m) in &h.evals {
            w.row(&[
                "normal_curve".into(),
                step.to_string(),
                "0".into(),
                format!("{m:.5}"),
            ]);
        }
    }

    // ---- right panel: probing windows
    let window = n_steps / 2; // paper: 500 of 1000 epochs
    println!("\n=== Fig 8 right: {window}-step q_min window probed across training ===");
    println!("{:>14} {:>12}", "window", "accuracy");
    // Paper protocol: probing runs train for 2x the normal duration so
    // every window position leaves the same recovery room; only the
    // window position varies.
    let probe_total = 2 * n_steps;
    let positions = [0.0, 0.125, 0.25, 0.375, 0.5];
    for &pos in &positions {
        let start = (pos * n_steps as f64) as usize;
        let mut accs = Vec::new();
        for trial in 0..trials {
            let s = Schedule::deficit(2.0, 8.0, start, start + window);
            let acc = run(&model, s, probe_total, trial)?;
            w.row(&[
                "probe".into(),
                start.to_string(),
                trial.to_string(),
                format!("{acc:.5}"),
            ]);
            accs.push(acc as f64);
        }
        let (m, s) = cpt::data::mean_std(&accs);
        println!("[{start:>4}, {:>4}) {m:>12.4} ± {s:.4}", start + window);
    }

    let path = cpt::results_dir().join("fig8_critical_periods.csv");
    w.write_to(&path)?;
    println!("\nwrote {}", path.display());
    println!("\nPaper shape: accuracy decays smoothly with R; probing shows the");
    println!("earliest window causes the largest permanent degradation.");
    Ok(())
}

//! Ablation: cycle count n (paper §3.2 step two — "we find that n = 8
//! performs consistently well"; Fig 2 bottom-left illustrates the knob).
//! Sweeps n ∈ {1, 2, 4, 8, 16} for CR and RR on the GCN workload.
//!
//!   cargo bench --bench ablation_cycles

use cpt::metrics::CsvWriter;
use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let steps = scale.steps(240, 480);
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let model = rt.load_model(manifest.model("gcn_qagg")?)?;

    let mut w = CsvWriter::new(&["schedule", "n", "trial", "accuracy", "gbitops"]);
    println!("=== Ablation: cycle count n (gcn_qagg, {steps} steps, q in [3,8]) ===\n");
    println!("{:<9} {:>4} {:>12} {:>12}", "schedule", "n", "accuracy", "GBitOps");
    for sched in ["CR", "RR"] {
        for n in [1usize, 2, 4, 8, 16] {
            // triangular variants need even n; CR/RR are repeated — fine.
            let mut accs = Vec::new();
            let mut gb = 0.0;
            for trial in 0..scale.trials() {
                let out = cpt::coordinator::run_one(
                    &model, "gcn_qagg", sched, 8.0, trial, steps, n, 0, false,
                )?;
                w.row(&[
                    sched.into(),
                    n.to_string(),
                    trial.to_string(),
                    format!("{:.5}", out.metric),
                    format!("{:.5}", out.gbitops),
                ]);
                accs.push(out.metric);
                gb = out.gbitops;
            }
            let (m, s) = cpt::data::mean_std(&accs);
            println!("{sched:<9} {n:>4} {m:>9.4} ± {s:.4} {gb:>9.4}");
        }
    }
    let path = cpt::results_dir().join("ablation_cycles.csv");
    w.write_to(&path)?;
    println!("\nwrote {}", path.display());
    println!("\nPaper: n = 8 performs consistently well (and n has no effect on");
    println!("cost for repeated schedules — only the cycling frequency changes).");
    Ok(())
}

//! Fig 6 reproduction: node classification — test accuracy vs GBitOps for
//! the schedule suite × q_max ∈ {6, 8}, on GCN (OGBN-Arxiv stand-in) and
//! GraphSAGE (OGBN-Products stand-in), each with FP-Agg and Q-Agg.
//!
//!   cargo bench --bench fig6_node_classification
//!
//! Set CPT_RUN_DIR=runs to persist per-cell artifacts and resume a
//! killed run where it stopped.

use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    for model in ["gcn_fpagg", "gcn_qagg", "sage_fpagg", "sage_qagg"] {
        let mut spec = SweepSpec::new(model);
        spec.trials = scale.trials();
        spec.steps = Some(scale.steps(240, 480));
        spec.apply_env_run_dir(&manifest)?;
        spec.log_run_dir();
        let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
        let rows = aggregate(&outs);
        let title = format!("Fig 6 ({model}): accuracy vs GBitOps");
        let rep = SweepReport::new(&title, "accuracy", true);
        rep.print(&rows);
        rep.write_csv_with_timing(
            &rows,
            timing,
            cpt::results_dir().join(format!("fig6_{model}.csv")),
        )?;
    }
    println!("\nPaper shape: on the Arxiv-like graph, Large schedules trail the");
    println!("baseline while Small/Medium match or beat it; on the Products-like");
    println!("graph nearly all CPT schedules beat the baseline at >2x savings.");
    Ok(())
}

//! Table 1 reproduction: ResNet deficit windows on the CIFAR/ImageNet
//! stand-ins — low-precision training applied during different windows;
//! test accuracy per window (mean ± std over trials).
//!
//!   cargo bench --bench table1_deficit_windows

use cpt::metrics::CsvWriter;
use cpt::prelude::*;
use cpt::schedule::Schedule;

fn run(
    model: &LoadedModel,
    name: &str,
    schedule: Schedule,
    total: usize,
    trial: usize,
) -> anyhow::Result<f32> {
    let mut data = dataset_for(name, 42 + trial as u64)?;
    let rec = recipe(name)?;
    let cfg = TrainConfig {
        total_steps: total,
        q_bwd: 8.0,
        eval_every: 0,
        seed: 5 + trial as i32,
        log_every: 8,
        verbose: false,
    };
    let mut t = Trainer::new(
        model,
        data.as_mut(),
        schedule,
        rec.lr_schedule(total),
        cfg,
    );
    Ok(t.run()?.final_eval_metric().unwrap_or(f32::NAN))
}

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let trials = scale.trials();
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    let mut w = CsvWriter::new(&["model", "window", "trial", "accuracy"]);

    // CIFAR stand-in: windows as fractions of the paper's 64K-iteration
    // run, scaled to our step budget. Paper windows: none, [0,16K] ...
    // [0,256K] (with 64K+256K extending past normal training), then
    // shifted windows [16K,144K] etc.
    let name = "cnn_tiny";
    let n_steps = scale.steps(128, 320);
    let model = rt.load_model(manifest.model(name)?)?;
    let u = n_steps / 4; // "16K" unit
    let windows: Vec<(String, usize, usize, usize)> = vec![
        // (label, start, end, total_steps)
        ("none".into(), 0, 0, n_steps),
        (format!("[0,{u}]"), 0, u, n_steps),
        (format!("[0,{}]", 2 * u), 0, 2 * u, n_steps),
        (format!("[0,{}]", 4 * u), 0, 4 * u, n_steps + u),
        (format!("[0,{}]", 6 * u), 0, 6 * u, n_steps + 2 * u),
        (format!("[{u},{}]", 3 * u), u, 3 * u, n_steps),
        (format!("[{},{}]", 2 * u, 4 * u), 2 * u, 4 * u, n_steps),
    ];

    println!("=== Table 1 (CIFAR stand-in, ResNet-tiny, {n_steps}-step runs) ===");
    println!("{:<16} {:>12}", "deficit window", "accuracy");
    for (label, start, end, total) in &windows {
        let mut accs = Vec::new();
        for trial in 0..trials {
            let s = if start == end {
                Schedule::static_q(8.0)
            } else {
                Schedule::deficit(3.0, 8.0, *start, *end)
            };
            let acc = run(&model, name, s, *total, trial)?;
            w.row(&[
                name.into(),
                label.clone(),
                trial.to_string(),
                format!("{acc:.5}"),
            ]);
            accs.push(acc as f64);
        }
        let (m, sd) = cpt::data::mean_std(&accs);
        println!("{label:<16} {m:>12.4} ± {sd:.4}");
    }

    // ImageNet stand-in: deficits at the beginning only (paper: compute
    // limits), R in {0, ~28%, ~111%} of the run as in [0,25]/[0,100] of 90
    // epochs.
    let name = "cnn_deep";
    let n_steps = scale.steps(96, 320);
    let model = rt.load_model(manifest.model(name)?)?;
    println!("\n=== Table 1 (ImageNet stand-in, deeper ResNet, {n_steps}-step runs) ===");
    println!("{:<16} {:>12}", "deficit window", "accuracy");
    for frac in [0.0, 0.28, 1.0] {
        let r = (frac * n_steps as f64) as usize;
        let label = if r == 0 { "none".into() } else { format!("[0,{r}]") };
        let mut accs = Vec::new();
        for trial in 0..trials {
            let s = if r == 0 {
                Schedule::static_q(8.0)
            } else {
                Schedule::deficit(4.0, 8.0, 0, r)
            };
            let acc = run(&model, name, s, n_steps.max(r), trial)?;
            w.row(&[
                name.into(),
                label.clone(),
                trial.to_string(),
                format!("{acc:.5}"),
            ]);
            accs.push(acc as f64);
        }
        let (m, sd) = cpt::data::mean_std(&accs);
        println!("{label:<16} {m:>12.4} ± {sd:.4}");
    }

    let path = cpt::results_dir().join("table1_deficit_windows.csv");
    w.write_to(&path)?;
    println!("\nwrote {}", path.display());
    println!("\nPaper shape: accuracy decays smoothly as the initial window grows;");
    println!("equal-length windows later in training recover to near-baseline.");
    Ok(())
}

//! Fig 3 reproduction: image classification — test accuracy vs training
//! GBitOps for the full schedule suite × q_max ∈ {6, 8}, on the CIFAR
//! stand-in (cnn_tiny) and the ImageNet stand-in (cnn_deep).
//!
//!   cargo bench --bench fig3_image_classification
//!   CPT_BENCH_SCALE=full cargo bench --bench fig3_image_classification
//!
//! Set CPT_RUN_DIR=runs to persist per-cell artifacts and resume a
//! killed run where it stopped (full-scale panels are hours long).

use cpt::prelude::*;

fn main() -> anyhow::Result<()> {
    let scale = cpt::bench_scale();
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    // The deeper ImageNet-stand-in panel only runs at full scale — at
    // quick scale its step budget would sit below the learning threshold
    // (reported as such rather than printing chance-level rows).
    let models: &[&str] = match scale {
        cpt::BenchScale::Quick => &["cnn_tiny"],
        cpt::BenchScale::Full => &["cnn_tiny", "cnn_deep"],
    };
    for &model in models {
        let mut spec = SweepSpec::new(model);
        spec.trials = scale.trials();
        spec.steps = Some(scale.steps(256, 320));
        spec.verbose = true;
        spec.apply_env_run_dir(&manifest)?;
        spec.log_run_dir();
        let (outs, timing) = run_sweep_timed(&manifest, &spec)?;
        let rows = aggregate(&outs);
        let title = format!(
            "Fig 3 ({}): accuracy vs GBitOps",
            if model == "cnn_tiny" { "CIFAR stand-in" } else { "ImageNet stand-in" }
        );
        let rep = SweepReport::new(&title, "accuracy", true);
        rep.print(&rows);
        rep.write_csv_with_timing(
            &rows,
            timing,
            cpt::results_dir().join(format!("fig3_{model}.csv")),
        )?;
    }
    println!("\nPaper shape: CPT variants cluster at lower GBitOps than STATIC;");
    println!("performance correlates with training compute; Large (RR/RTH)");
    println!("saves most but may trail Small (ER/ETH) in accuracy.");
    Ok(())
}

#!/usr/bin/env bash
# Tier-1 verification gate for the cpt crate: format, lint, tests, and
# (with --smoke) a 1-rep perf_hotpath bench run on mlp only, so the
# bench target is compiled-and-exercised without paying full bench cost.
#
#   scripts/check.sh            # fmt + clippy + tests
#   scripts/check.sh --smoke    # ... + perf_hotpath smoke run
set -euo pipefail

cd "$(dirname "$0")/../rust"

SMOKE=0
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    *) echo "check.sh: unknown arg '$a' (known: --smoke)" >&2; exit 2 ;;
  esac
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "check.sh: cargo not on PATH — cannot verify (toolchain-less container)" >&2
  exit 0
fi

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo test -q"
cargo test -q

if [ "$SMOKE" = 1 ]; then
  if [ -f artifacts/manifest.json ]; then
    echo "== perf_hotpath --smoke (1 rep, mlp only)"
    cargo bench --bench perf_hotpath -- --smoke
  else
    echo "== perf_hotpath --smoke: artifacts/manifest.json missing — building only"
    cargo build --benches
  fi
fi

echo "check.sh: OK"

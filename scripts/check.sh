#!/usr/bin/env bash
# Tier-1 verification gate for the cpt crate: format, lint, tests, and
# (with --smoke) a 1-rep perf_hotpath bench run on mlp only plus a
# 2-shard sweep + merge end-to-end pass, so the bench target and the
# sharded orchestration path are compiled-and-exercised without paying
# full bench cost.
#
#   scripts/check.sh            # fmt + clippy + tests
#   scripts/check.sh --smoke    # ... + perf_hotpath + shard/merge smoke
set -euo pipefail

cd "$(dirname "$0")/../rust"

SMOKE=0
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    *) echo "check.sh: unknown arg '$a' (known: --smoke)" >&2; exit 2 ;;
  esac
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "check.sh: cargo not on PATH — cannot verify (toolchain-less container)" >&2
  exit 0
fi

# Formatting needs no dependency resolution — run it first so even
# vendor-less environments (stock CI runners) enforce it.
echo "== cargo fmt --check"
cargo fmt --check

# The xla PJRT bindings come from an offline vendor set, never crates.io.
# On runners known to lack that vendor configuration (stock CI), setting
# CPT_ALLOW_MISSING_VENDOR=1 downgrades the remaining gates to a clean
# fmt-only pass. Anywhere else a resolution failure is a real breakage
# (vendor config regressed, Cargo.toml broken) and must fail loudly —
# a silent skip here would green-light compile-breaking commits.
if ! cargo metadata --format-version 1 --offline >/dev/null 2>&1; then
  if [ "${CPT_ALLOW_MISSING_VENDOR:-0}" = 1 ]; then
    echo "check.sh: offline dependency resolution unavailable — fmt-only pass (CPT_ALLOW_MISSING_VENDOR=1)" >&2
    exit 0
  fi
  echo "check.sh: cannot resolve dependencies offline (xla vendor set missing or broken)" >&2
  echo "check.sh: fix the vendor config, or export CPT_ALLOW_MISSING_VENDOR=1 on vendor-less runners" >&2
  exit 1
fi

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo test -q"
cargo test -q

if [ "$SMOKE" = 1 ]; then
  if [ -f artifacts/manifest.json ]; then
    echo "== perf_hotpath --smoke (1 rep, mlp only)"
    cargo bench --bench perf_hotpath -- --smoke

    echo "== 2-shard sweep + merge smoke (mlp, 4 cells)"
    # serial run vs (shard 1/2 + shard 2/2 + merge): the deterministic
    # aggregate columns (everything except the wall-clock ones) must be
    # byte-identical. Also exercises resume: re-running shard 1 must
    # skip all its cells.
    SMOKE_DIR="$(mktemp -d)"
    trap 'rm -rf "$SMOKE_DIR"' EXIT
    CPT="cargo run --release --quiet --bin cpt --"
    SWEEP_ARGS="--model mlp --schedules CR,RR --qmaxes 8 --trials 2 --steps 8"
    $CPT sweep $SWEEP_ARGS --csv "$SMOKE_DIR/serial.csv"
    $CPT sweep $SWEEP_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/s1"
    $CPT sweep $SWEEP_ARGS --shard 2/2 --run-dir "$SMOKE_DIR/s2"
    RESUME_OUT="$($CPT sweep $SWEEP_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/s1" --resume)"
    case "$RESUME_OUT" in
      *"2 resumed from artifacts"*) ;;
      *) echo "check.sh: shard resume did not skip completed cells" >&2; exit 1 ;;
    esac
    $CPT merge --csv "$SMOKE_DIR/merged.csv" "$SMOKE_DIR/s1" "$SMOKE_DIR/s2"
    if ! diff <(cut -d, -f1-8 "$SMOKE_DIR/serial.csv") "$SMOKE_DIR/merged.csv"; then
      echo "check.sh: sharded merge CSV differs from serial sweep" >&2
      exit 1
    fi
    echo "shard/merge smoke: serial and merged aggregates are identical"
  else
    echo "== bench/sweep smoke: artifacts/manifest.json missing — building only"
    cargo build --benches
  fi
fi

echo "check.sh: OK"

#!/usr/bin/env bash
# Tier-1 verification gate for the cpt crate: format, lint, tests, and
# (with --smoke) a 1-rep perf_hotpath bench run on mlp only plus six
# end-to-end orchestration passes — a 2-shard sweep + merge, a 2-shard
# *adaptive-policy* sweep killed mid-run / resumed / merged, a 3-sweep
# campaign (one member adaptive) on the sequential scheduler that is
# killed mid-run, resumed, cross-merged, and gc'd, the same campaign
# through the global scheduler (--jobs 2, one worker pool over all
# sweeps) whose merged CSVs must be byte-identical to the sequential
# pass, and a lease-claim sweep where one claimer is killed and one
# stalls mid-run yet the survivors' CSVs match the static-shard
# baseline, and a `cpt serve` daemon pass (--concurrent-jobs 2, one
# persistent shared worker pool) whose fetched CSVs must be
# byte-identical to the direct campaign, whose identical resubmission
# must be a spec-hash cache hit, whose second distinct shared-model
# campaign must report zero cross-job compiles, whose `cpt stats` verb
# must answer live, and whose finished job dirs `cpt gc --max-age`
# prunes — plus a `--trace` campaign whose merged CSVs must be
# byte-identical to the traceless ground truth (tracing is
# result-inert) and whose JSONL trace `cpt trace` must fold into
# per-worker timelines — so the bench targets and the whole
# coordinator surface are compiled-and-exercised without paying full
# bench cost.
#
#   scripts/check.sh            # fmt + clippy + tests
#   scripts/check.sh --unit     # fmt + lib unit tests + the non-PJRT
#                               # integration files (tests/campaign.rs,
#                               # tests/global_sched.rs, tests/policy.rs,
#                               # tests/lease.rs, tests/aot.rs,
#                               # tests/serve_proto.rs, tests/serve.rs,
#                               # tests/obs.rs);
#                               # needs no HLO artifacts — the CI
#                               # test-unit job runs this tier
#   scripts/check.sh --smoke    # ... + perf_hotpath + fig_campaign_sched
#                               # + fig_policy + shard/merge, policy, and
#                               # campaign smokes
set -euo pipefail

cd "$(dirname "$0")/../rust"

SMOKE=0
UNIT=0
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    --unit) UNIT=1 ;;
    *) echo "check.sh: unknown arg '$a' (known: --smoke, --unit)" >&2; exit 2 ;;
  esac
done

if ! command -v cargo >/dev/null 2>&1; then
  echo "check.sh: cargo not on PATH — cannot verify (toolchain-less container)" >&2
  exit 0
fi

# Formatting needs no dependency resolution — run it first so even
# vendor-less environments (stock CI runners) enforce it.
echo "== cargo fmt --check"
cargo fmt --check

# The xla PJRT bindings come from an offline vendor set, never crates.io.
# On runners known to lack that vendor configuration (stock CI), setting
# CPT_ALLOW_MISSING_VENDOR=1 downgrades the remaining gates to a clean
# fmt-only pass. Anywhere else a resolution failure is a real breakage
# (vendor config regressed, Cargo.toml broken) and must fail loudly —
# a silent skip here would green-light compile-breaking commits.
if ! cargo metadata --format-version 1 --offline >/dev/null 2>&1; then
  if [ "${CPT_ALLOW_MISSING_VENDOR:-0}" = 1 ]; then
    echo "check.sh: offline dependency resolution unavailable — fmt-only pass (CPT_ALLOW_MISSING_VENDOR=1)" >&2
    exit 0
  fi
  echo "check.sh: cannot resolve dependencies offline (xla vendor set missing or broken)" >&2
  echo "check.sh: fix the vendor config, or export CPT_ALLOW_MISSING_VENDOR=1 on vendor-less runners" >&2
  exit 1
fi

if [ "$UNIT" = 1 ]; then
  # The unit tier: everything that runs without the PJRT runtime or AOT
  # artifacts — the crate's #[cfg(test)] suites (store, plan, campaign,
  # schedules, json, ...) plus tests/campaign.rs, which drives planning,
  # persistence, corruption handling, status, gc, and merging end to end
  # on fabricated outcomes.
  echo "== cargo test -q --lib (unit tier)"
  cargo test -q --lib
  echo "== cargo test -q --test campaign (fabricated-outcome integration)"
  cargo test -q --test campaign
  echo "== cargo test -q --test global_sched (fabricated global scheduler)"
  cargo test -q --test global_sched
  echo "== cargo test -q --test policy (fabricated adaptive policies)"
  cargo test -q --test policy
  echo "== cargo test -q --test lease (fabricated lease-based claiming)"
  cargo test -q --test lease
  echo "== cargo test -q --test aot (fabricated persistent AOT cache)"
  cargo test -q --test aot
  echo "== cargo test -q --test serve_proto (serve wire-protocol round-trip + malformed-input matrix)"
  cargo test -q --test serve_proto
  echo "== cargo test -q --test serve (fabricated serve daemon: dedupe, recovery, failure)"
  cargo test -q --test serve
  echo "== cargo test -q --test obs (trace round-trip, truncated tail, metrics, analyzer)"
  cargo test -q --test obs
  echo "check.sh: OK (unit tier)"
  exit 0
fi

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "== cargo test -q"
cargo test -q

if [ "$SMOKE" = 1 ]; then
  if [ -f artifacts/manifest.json ]; then
    echo "== perf_hotpath --smoke (1 rep, mlp only)"
    cargo bench --bench perf_hotpath -- --smoke

    echo "== 2-shard sweep + merge smoke (mlp, 4 cells)"
    # serial run vs (shard 1/2 + shard 2/2 + merge): the deterministic
    # aggregate columns (everything except the wall-clock ones) must be
    # byte-identical. Also exercises resume: re-running shard 1 must
    # skip all its cells.
    SMOKE_DIR="$(mktemp -d)"
    trap 'rm -rf "$SMOKE_DIR"' EXIT
    CPT="cargo run --release --quiet --bin cpt --"
    SWEEP_ARGS="--model mlp --schedules CR,RR --qmaxes 8 --trials 2 --steps 8"
    $CPT sweep $SWEEP_ARGS --csv "$SMOKE_DIR/serial.csv"
    $CPT sweep $SWEEP_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/s1"
    $CPT sweep $SWEEP_ARGS --shard 2/2 --run-dir "$SMOKE_DIR/s2"
    RESUME_OUT="$($CPT sweep $SWEEP_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/s1" --resume)"
    case "$RESUME_OUT" in
      *"2 resumed from artifacts"*) ;;
      *) echo "check.sh: shard resume did not skip completed cells" >&2; exit 1 ;;
    esac
    $CPT merge --csv "$SMOKE_DIR/merged.csv" "$SMOKE_DIR/s1" "$SMOKE_DIR/s2"
    if ! diff <(cut -d, -f1-10 "$SMOKE_DIR/serial.csv") "$SMOKE_DIR/merged.csv"; then
      echo "check.sh: sharded merge CSV differs from serial sweep" >&2
      exit 1
    fi
    echo "shard/merge smoke: serial and merged aggregates are identical"

    echo "== adaptive-policy sweep smoke (loss_plateau, 2 shards, kill + resume + merge)"
    # An adaptive policy makes the realized q_t trace data-dependent; the
    # gate pins the property everything downstream relies on: the trace
    # is deterministic, so a killed, resumed, sharded run merges
    # byte-identically to a serial one (realized mean_q/realized_cost
    # columns included).
    POL_ARGS="--model mlp --policy loss_plateau --qmaxes 8 --trials 4 --steps 8"
    $CPT sweep $POL_ARGS --csv "$SMOKE_DIR/pol_serial.csv"
    if CPT_HALT_AFTER_CELLS=1 $CPT sweep $POL_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/p1"; then
      echo "check.sh: policy sweep crash injection did not fire" >&2; exit 1
    fi
    POL_RESUME="$($CPT sweep $POL_ARGS --shard 1/2 --run-dir "$SMOKE_DIR/p1" --resume)"
    case "$POL_RESUME" in
      *"1 resumed from artifacts"*) ;;
      *) echo "check.sh: policy shard resume did not reuse the recorded cell" >&2; exit 1 ;;
    esac
    $CPT sweep $POL_ARGS --shard 2/2 --run-dir "$SMOKE_DIR/p2"
    $CPT merge --csv "$SMOKE_DIR/pol_merged.csv" "$SMOKE_DIR/p1" "$SMOKE_DIR/p2"
    if ! diff <(cut -d, -f1-10 "$SMOKE_DIR/pol_serial.csv") "$SMOKE_DIR/pol_merged.csv"; then
      echo "check.sh: adaptive-policy sharded merge differs from the serial sweep" >&2
      exit 1
    fi
    # the realized columns are present and the status report surfaces
    # the per-cell trace summary
    if ! head -1 "$SMOKE_DIR/pol_serial.csv" | grep -q "mean_q,realized_cost"; then
      echo "check.sh: stable CSV is missing the realized trace columns" >&2
      exit 1
    fi
    if ! $CPT status "$SMOKE_DIR/p1" | grep -q "realized: mean q/qmax"; then
      echo "check.sh: status does not report the realized trace summary" >&2
      exit 1
    fi
    echo "policy smoke: adaptive shards kill/resume/merge byte-identically to serial"

    echo "== campaign smoke (sequential scheduler: 3 sweeps x 2 shards, kill + resume + merge + gc)"
    # member "c" is adaptive: the campaign path carries [sweep.policy]-
    # style member policies through shard/resume/merge on both schedulers
    CAMP_TOML="$SMOKE_DIR/campaign.toml"
    cat > "$CAMP_TOML" <<'EOF'
[campaign]
name = "smoke"

[[campaign.sweep]]
name = "a"
model = "mlp"
schedules = ["CR", "RR"]
q_maxes = [8]
trials = 1
steps = 8

[[campaign.sweep]]
name = "b"
model = "mlp"
schedules = ["CR", "STATIC"]
q_maxes = [8]
trials = 1
steps = 10

[[campaign.sweep]]
name = "c"
model = "mlp"
policy = "loss_plateau"
q_maxes = [8]
trials = 2
steps = 8
EOF
    R1="$SMOKE_DIR/camp1"
    R2="$SMOKE_DIR/camp2"
    # Shard 1/2, killed after its first freshly computed cell.
    # CPT_HALT_AFTER_CELLS is the deterministic stand-in for `kill`:
    # the abort fires after the artifact + manifests are durable, which
    # is exactly the state an external kill leaves behind.
    if CPT_HALT_AFTER_CELLS=1 $CPT campaign --file "$CAMP_TOML" --run-dir "$R1" --shard 1/2 --scheduler sequential; then
      echo "check.sh: campaign crash injection did not fire" >&2; exit 1
    fi
    if ! $CPT status "$R1" | grep -q "total: done 1/3"; then
      echo "check.sh: status after kill should report done 1/3" >&2
      $CPT status "$R1" >&2 || true
      exit 1
    fi
    # resume completes the shard, reusing the recorded cell
    RESUME_OUT="$($CPT campaign --file "$CAMP_TOML" --run-dir "$R1" --shard 1/2 --scheduler sequential --resume)"
    case "$RESUME_OUT" in
      *"(1 resumed)"*) ;;
      *) echo "check.sh: campaign resume did not reuse the recorded cell" >&2; exit 1 ;;
    esac
    if ! $CPT status "$R1" | grep -q "total: done 3/3"; then
      echo "check.sh: status after resume should report done 3/3" >&2; exit 1
    fi
    # shard 2/2 runs uninterrupted
    $CPT campaign --file "$CAMP_TOML" --run-dir "$R2" --shard 2/2 --scheduler sequential
    if ! $CPT status "$R2" | grep -q "total: done 3/3"; then
      echo "check.sh: shard 2/2 status should report done 3/3" >&2; exit 1
    fi
    # cross-merge the roots, then compare every member CSV against an
    # independent serial run of the same sweep — byte-identical (the
    # adaptive member against an independent --policy sweep)
    $CPT merge --csv-dir "$SMOKE_DIR/campout" "$R1" "$R2"
    $CPT sweep --model mlp --schedules CR,RR --qmaxes 8 --trials 1 --steps 8 --csv "$SMOKE_DIR/ind_a.csv"
    $CPT sweep --model mlp --schedules CR,STATIC --qmaxes 8 --trials 1 --steps 10 --csv "$SMOKE_DIR/ind_b.csv"
    $CPT sweep --model mlp --policy loss_plateau --qmaxes 8 --trials 2 --steps 8 --csv "$SMOKE_DIR/ind_c.csv"
    for m in a b c; do
      if ! diff <(cut -d, -f1-10 "$SMOKE_DIR/ind_$m.csv") "$SMOKE_DIR/campout/$m.csv"; then
        echo "check.sh: campaign member '$m' CSV differs from its independent sweep" >&2
        exit 1
      fi
    done
    # gc both roots; the re-merged CSVs must not change by a byte
    $CPT gc "$R1" >/dev/null
    $CPT gc "$R2" >/dev/null
    $CPT merge --csv-dir "$SMOKE_DIR/campout_gc" "$R1" "$R2"
    for f in a.csv b.csv c.csv campaign.csv; do
      if ! diff "$SMOKE_DIR/campout/$f" "$SMOKE_DIR/campout_gc/$f"; then
        echo "check.sh: $f changed across gc" >&2
        exit 1
      fi
    done
    echo "campaign smoke: killed+resumed shards merge identically to independent sweeps (and survive gc)"

    echo "== global-scheduler campaign smoke (--jobs 2, one pool over both sweeps, kill + resume + merge)"
    # The same campaign through the global scheduler: one shared worker
    # pool claims cells across both members with a per-worker compiled-
    # executable cache. Killed after the first fresh cell, resumed, and
    # cross-merged — every CSV must be byte-identical to the sequential
    # scheduler's output above.
    G1="$SMOKE_DIR/gcamp1"
    G2="$SMOKE_DIR/gcamp2"
    if CPT_HALT_AFTER_CELLS=1 $CPT campaign --file "$CAMP_TOML" --run-dir "$G1" --shard 1/2 --jobs 2 --scheduler global; then
      echo "check.sh: global campaign crash injection did not fire" >&2; exit 1
    fi
    if ! $CPT status "$G1" | grep -q "total: done 1/3"; then
      echo "check.sh: global status after kill should report done 1/3" >&2
      $CPT status "$G1" >&2 || true
      exit 1
    fi
    RESUME_OUT="$($CPT campaign --file "$CAMP_TOML" --run-dir "$G1" --shard 1/2 --jobs 2 --scheduler global --resume)"
    case "$RESUME_OUT" in
      *"(1 resumed)"*) ;;
      *) echo "check.sh: global campaign resume did not reuse the recorded cell" >&2; exit 1 ;;
    esac
    # the manifest records the pool's compile accounting for status
    if ! $CPT status "$G1" | grep -q "scheduler:"; then
      echo "check.sh: status should surface global-scheduler compile stats" >&2
      $CPT status "$G1" >&2 || true
      exit 1
    fi
    $CPT campaign --file "$CAMP_TOML" --run-dir "$G2" --shard 2/2 --jobs 2 --scheduler global
    $CPT merge --csv-dir "$SMOKE_DIR/campout_global" "$G1" "$G2"
    for f in a.csv b.csv c.csv campaign.csv; do
      if ! diff "$SMOKE_DIR/campout/$f" "$SMOKE_DIR/campout_global/$f"; then
        echo "check.sh: $f differs between sequential and global schedulers" >&2
        exit 1
      fi
    done
    echo "global-scheduler smoke: killed+resumed global-pool shards merge byte-identically to the sequential scheduler"

    echo "== trace smoke (--trace campaign: result-inert, analyzable timelines)"
    # Tracing is result-inert by contract: the same campaign with
    # --trace must produce byte-identical merged CSVs, with the trace
    # living only under <run-dir>/trace/. The analyzer must then
    # reconstruct per-worker timelines with compile/exec breakdowns
    # from the traced run's JSONL.
    T1="$SMOKE_DIR/tcamp1"
    T2="$SMOKE_DIR/tcamp2"
    $CPT campaign --file "$CAMP_TOML" --run-dir "$T1" --shard 1/2 --jobs 2 --scheduler global --trace
    $CPT campaign --file "$CAMP_TOML" --run-dir "$T2" --shard 2/2 --jobs 2 --scheduler global --trace
    $CPT merge --csv-dir "$SMOKE_DIR/campout_traced" "$T1" "$T2"
    for f in a.csv b.csv c.csv campaign.csv; do
      if ! diff "$SMOKE_DIR/campout/$f" "$SMOKE_DIR/campout_traced/$f"; then
        echo "check.sh: $f differs with tracing on — tracing is not result-inert" >&2
        exit 1
      fi
    done
    if [ ! -d "$T1/trace" ]; then
      echo "check.sh: --trace produced no trace/ dir under the run dir" >&2
      exit 1
    fi
    TRACE_OUT="$($CPT trace "$T1")"
    if ! echo "$TRACE_OUT" | grep -q "^worker "; then
      echo "check.sh: cpt trace did not report a per-worker breakdown" >&2
      echo "$TRACE_OUT" >&2
      exit 1
    fi
    if ! echo "$TRACE_OUT" | grep -q "compile="; then
      echo "check.sh: cpt trace worker rows are missing the compile column" >&2
      echo "$TRACE_OUT" >&2
      exit 1
    fi
    # strict CPT_LOG parsing: a typo'd level is a loud startup error,
    # never a silent fallback to the default
    if CPT_LOG=vrbose $CPT status "$T1" >/dev/null 2>&1; then
      echo "check.sh: unparsable CPT_LOG should fail loudly" >&2
      exit 1
    fi
    echo "trace smoke: traced CSVs byte-identical to traceless; cpt trace reconstructs worker timelines"

    echo "== lease-claim sweep smoke (one claimer killed, one stalled; vs the static-shard baseline)"
    # Dynamic claiming must survive dead and wedged claimers and still
    # match the static path byte-for-byte on the deterministic CSV
    # columns. Claimer 'dead-a' is halt-injected after its first fresh
    # cell (a dead node leaving abandoned leases); 'slow-b' stalls for
    # 6s while holding leases (a wedged node: no heartbeats, late
    # commits must be refused); 'live-c' runs alongside, steals the
    # expired leases, and finishes. Both survivors must exit 0 and each
    # report the complete sweep.
    CLAIM_RUN="$SMOKE_DIR/claim"
    if CPT_HALT_AFTER_CELLS=1 CPT_LEASE_SECS=1 $CPT sweep $SWEEP_ARGS --run-dir "$CLAIM_RUN" --claim dead-a; then
      echo "check.sh: claim crash injection did not fire" >&2; exit 1
    fi
    if ! $CPT status "$CLAIM_RUN" | grep -q "claimer 'dead-a'"; then
      echo "check.sh: status does not surface the dead claimer's liveness" >&2
      $CPT status "$CLAIM_RUN" >&2 || true
      exit 1
    fi
    CPT_STALL_AFTER_CELLS=1 CPT_STALL_SECS=6 CPT_LEASE_SECS=1 \
      $CPT sweep $SWEEP_ARGS --run-dir "$CLAIM_RUN" --claim slow-b --csv "$SMOKE_DIR/claim_b.csv" &
    CLAIM_B_PID=$!
    sleep 1
    CPT_LEASE_SECS=1 $CPT sweep $SWEEP_ARGS --run-dir "$CLAIM_RUN" --claim live-c --csv "$SMOKE_DIR/claim_c.csv"
    if ! wait "$CLAIM_B_PID"; then
      echo "check.sh: the stalled claimer should recover and exit cleanly" >&2; exit 1
    fi
    for f in claim_b.csv claim_c.csv; do
      if ! diff <(cut -d, -f1-10 "$SMOKE_DIR/serial.csv") <(cut -d, -f1-10 "$SMOKE_DIR/$f"); then
        echo "check.sh: $f differs from the static-shard baseline" >&2
        exit 1
      fi
    done
    if ! $CPT status "$CLAIM_RUN" | grep -q "4 committed"; then
      echo "check.sh: claim status should report the full board committed" >&2
      $CPT status "$CLAIM_RUN" >&2 || true
      exit 1
    fi
    echo "claim smoke: dead + stalled claimers survived; outputs byte-identical to the static shards"

    echo "== AOT warm-start smoke (one persistent cache dir, fresh processes)"
    # The shared-model campaign twice against one CPT_AOT_CACHE dir. If
    # the backend can serialize executables, the second process must
    # report zero compiles (warm start straight from disk). The vendored
    # binding currently cannot — the runtime says so once per process —
    # which keeps the cache inert and soft-passes this gate. Either way,
    # a further run over a deliberately corrupted cache must fall back
    # to compiling, and every run's CSVs must be byte-identical to the
    # cache-less ground truth above: the cache is an execution knob,
    # never a result input.
    AOT_DIR="$SMOKE_DIR/aotcache"
    CPT_AOT_CACHE="$AOT_DIR" $CPT campaign --file "$CAMP_TOML" --run-dir "$SMOKE_DIR/aot1" \
      --jobs 2 --scheduler global --csv-dir "$SMOKE_DIR/aotout1" >/dev/null 2>&1
    AOT_OUT="$(CPT_AOT_CACHE="$AOT_DIR" $CPT campaign --file "$CAMP_TOML" --run-dir "$SMOKE_DIR/aot2" \
      --jobs 2 --scheduler global --csv-dir "$SMOKE_DIR/aotout2" 2>&1)"
    case "$AOT_OUT" in
      *"cannot serialize executables"*)
        echo "aot smoke: backend cannot serialize executables — cache inert, soft pass" ;;
      *" 0 compile(s)"*)
        echo "aot smoke: second process warm-started with zero compiles" ;;
      *)
        echo "check.sh: second process over a warm AOT cache still compiled" >&2
        echo "$AOT_OUT" >&2
        exit 1 ;;
    esac
    if [ -d "$AOT_DIR" ]; then
      for f in "$AOT_DIR"/*/*.bin; do
        [ -e "$f" ] || continue
        printf 'CORRUPT' >> "$f"
      done
    fi
    CPT_AOT_CACHE="$AOT_DIR" $CPT campaign --file "$CAMP_TOML" --run-dir "$SMOKE_DIR/aot3" \
      --jobs 2 --scheduler global --csv-dir "$SMOKE_DIR/aotout3" >/dev/null 2>&1
    for d in aotout1 aotout2 aotout3; do
      for f in a.csv b.csv c.csv campaign.csv; do
        if ! diff "$SMOKE_DIR/campout/$f" "$SMOKE_DIR/$d/$f"; then
          echo "check.sh: $d/$f differs from the cache-less ground truth" >&2
          exit 1
        fi
      done
    done
    # cache maintenance CLI over the same dir (creates it when the
    # backend never populated it): status, budgeted gc, and the generic
    # gc entry point routed by the cache marker
    $CPT cache status --aot-cache "$AOT_DIR" | grep -q "serialization support:"
    $CPT cache gc --aot-cache "$AOT_DIR" >/dev/null
    $CPT gc "$AOT_DIR" >/dev/null
    echo "aot smoke: CSVs byte-identical across cold, warm, and corrupted-cache runs"

    echo "== serve smoke (shared pool: 2 jobs, cross-job warm compiles + spec-hash cache hit)"
    # A long-running `cpt serve` daemon with the persistent shared
    # worker pool (--concurrent-jobs 2). The first submission executes
    # through the pool; its fetched CSVs must be byte-identical to the
    # direct-campaign ground truth in campout/. The identical
    # resubmission must be answered straight from the store (cache-hit
    # line, zero new compiles/cells). A second, distinct campaign
    # sharing the same model is then submitted: its CSVs must match its
    # own direct ground truth AND its per-job pool stats in `cpt jobs`
    # must show zero compiles — the cross-job warm start. Finally the
    # daemon shuts down cleanly and `cpt gc --max-age` prunes the
    # finished job dirs from the serve root.
    CAMP2_TOML="$SMOKE_DIR/campaign2.toml"
    cat > "$CAMP2_TOML" <<'EOF'
[campaign]
name = "smoke2"

[[campaign.sweep]]
name = "d"
model = "mlp"
schedules = ["CR", "RR"]
q_maxes = [8]
trials = 1
steps = 9

[[campaign.sweep]]
name = "e"
model = "mlp"
schedules = ["CR", "STATIC"]
q_maxes = [8]
trials = 1
steps = 12
EOF
    # direct ground truth for the second campaign
    $CPT campaign --file "$CAMP2_TOML" --run-dir "$SMOKE_DIR/camp2direct" \
      --jobs 2 --scheduler global --csv-dir "$SMOKE_DIR/campout2"
    SERVE_ROOT="$SMOKE_DIR/serve"
    # run the daemon from the built binary (not `cargo run`) so the
    # trap's kill reaches the daemon itself, never a cargo wrapper
    cargo build --release --quiet --bin cpt
    target/release/cpt serve --root "$SERVE_ROOT" --listen 127.0.0.1:0 \
      --jobs 2 --concurrent-jobs 2 \
      > "$SMOKE_DIR/serve.log" 2>&1 &
    SERVE_PID=$!
    trap 'kill "$SERVE_PID" 2>/dev/null || true; rm -rf "$SMOKE_DIR"' EXIT
    for _ in $(seq 1 240); do
      [ -f "$SERVE_ROOT/serve-addr" ] && break
      if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "check.sh: serve daemon died before binding" >&2
        cat "$SMOKE_DIR/serve.log" >&2 || true
        exit 1
      fi
      sleep 0.5
    done
    if [ ! -f "$SERVE_ROOT/serve-addr" ]; then
      echo "check.sh: serve daemon never published its address" >&2
      cat "$SMOKE_DIR/serve.log" >&2 || true
      exit 1
    fi
    ADDR="$(cat "$SERVE_ROOT/serve-addr")"
    $CPT submit --connect "$ADDR" --file "$CAMP_TOML" --wait \
      --out "$SMOKE_DIR/servefetch1"
    SUB2="$($CPT submit --connect "$ADDR" --file "$CAMP_TOML" --wait \
      --out "$SMOKE_DIR/servefetch2")"
    case "$SUB2" in
      *"cache hit"*) ;;
      *)
        echo "check.sh: identical resubmission was not served from the cache" >&2
        echo "$SUB2" >&2
        exit 1 ;;
    esac
    for d in servefetch1 servefetch2; do
      for f in a.csv b.csv c.csv campaign.csv; do
        if ! diff "$SMOKE_DIR/campout/$f" "$SMOKE_DIR/$d/$f"; then
          echo "check.sh: served $d/$f differs from the direct-campaign ground truth" >&2
          exit 1
        fi
      done
    done
    if ! $CPT status "$SERVE_ROOT" | grep -q "done"; then
      echo "check.sh: cpt status on the serve root should list the finished job" >&2
      $CPT status "$SERVE_ROOT" >&2 || true
      exit 1
    fi
    if ! $CPT jobs --connect "$ADDR" | grep -q "done"; then
      echo "check.sh: cpt jobs should list the finished job over the wire" >&2
      exit 1
    fi
    # second, distinct campaign on the warm pool: byte-identical CSVs,
    # zero compiles (4 cells, all in-memory cache hits -> "0/4/0" in the
    # compiles/hits/disk column of `cpt jobs`)
    $CPT submit --connect "$ADDR" --file "$CAMP2_TOML" --wait \
      --out "$SMOKE_DIR/servefetch3"
    for f in d.csv e.csv campaign.csv; do
      if ! diff "$SMOKE_DIR/campout2/$f" "$SMOKE_DIR/servefetch3/$f"; then
        echo "check.sh: served $f differs from the second campaign's direct ground truth" >&2
        exit 1
      fi
    done
    JOBS_OUT="$($CPT jobs --connect "$ADDR")"
    if ! echo "$JOBS_OUT" | grep -q " 0/4/0 "; then
      echo "check.sh: second job should report zero compiles (cross-job warm start)" >&2
      echo "$JOBS_OUT" >&2
      exit 1
    fi
    # the stats verb: uptime, jobs by state, request/error counters,
    # pool compile/hit totals — answered live before shutdown
    STATS_OUT="$($CPT stats --connect "$ADDR")"
    if ! echo "$STATS_OUT" | grep -q "uptime:"; then
      echo "check.sh: cpt stats did not report uptime" >&2
      echo "$STATS_OUT" >&2
      exit 1
    fi
    if ! echo "$STATS_OUT" | grep -q "requests answered:"; then
      echo "check.sh: cpt stats did not report the request counter" >&2
      echo "$STATS_OUT" >&2
      exit 1
    fi
    if ! echo "$STATS_OUT" | grep -q "done"; then
      echo "check.sh: cpt stats jobs-by-state should list the finished jobs" >&2
      echo "$STATS_OUT" >&2
      exit 1
    fi
    $CPT shutdown --connect "$ADDR"
    if ! wait "$SERVE_PID"; then
      echo "check.sh: serve daemon did not exit cleanly after shutdown" >&2
      cat "$SMOKE_DIR/serve.log" >&2 || true
      exit 1
    fi
    trap 'rm -rf "$SMOKE_DIR"' EXIT
    # serve-root gc: both finished job dirs are prunable once aged out
    GC_OUT="$($CPT gc "$SERVE_ROOT" --max-age 0)"
    case "$GC_OUT" in
      *"removed 2 finished job dir(s)"*) ;;
      *)
        echo "check.sh: serve-root gc should prune both finished jobs" >&2
        echo "$GC_OUT" >&2
        exit 1 ;;
    esac
    echo "serve smoke: resubmission cached, cross-job compiles zero, fetched CSVs byte-identical to direct runs"

    echo "== fig_campaign_sched bench (executable-cache compile accounting)"
    cargo bench --bench fig_campaign_sched

    echo "== fig_policy bench (adaptive policies vs static schedules)"
    cargo bench --bench fig_policy
  else
    echo "== bench/sweep smoke: artifacts/manifest.json missing — building only"
    cargo build --benches
  fi
fi

echo "check.sh: OK"

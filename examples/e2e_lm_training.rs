//! End-to-end driver (DESIGN.md / EXPERIMENTS.md §E2E): train the
//! transformer language model on the synthetic Markov corpus with cyclic
//! precision training, entirely from Rust — the full three-layer stack in
//! one run.
//!
//!   make artifacts && cargo run --release --example e2e_lm_training
//!
//! What it does:
//!   * loads the AOT-compiled transformer_lm artifacts via PJRT,
//!   * trains for a few hundred optimizer steps under the CR schedule
//!     (and a STATIC baseline for contrast),
//!   * logs the loss curve + per-step precision to results/e2e_lm.csv,
//!   * reports final perplexity, effective GBitOps, and throughput.

use anyhow::Result;
use cpt::prelude::*;

fn main() -> Result<()> {
    let steps = std::env::var("E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let spec = manifest.model("transformer_lm")?;
    println!(
        "model transformer_lm: {} params, chunk K={}, {:.1} MFLOP qGEMM/fwd",
        spec.param_count,
        spec.chunk,
        spec.q_gemm_flops_fwd as f64 / 1e6
    );
    let model = rt.load_model(spec)?;
    println!(
        "compiled: init {:.0}ms, chunk {:.0}ms, step {:.0}ms, eval {:.0}ms",
        model.init.compile_ms,
        model.train_chunk.compile_ms,
        model.train_step.compile_ms,
        model.eval.compile_ms
    );

    let mut outs = Vec::new();
    for sched in ["CR", "STATIC"] {
        let t0 = std::time::Instant::now();
        let out = cpt::coordinator::run_one(
            &model,
            "transformer_lm",
            sched,
            8.0,
            0,
            steps,
            8,
            (steps / 8).max(1),
            true, // verbose: stream eval lines
        )?;
        let dt = t0.elapsed().as_secs_f64();
        let tokens = steps as f64 * 16.0 * 32.0; // batch x seq
        println!(
            "\n[{sched}] final perplexity {:.3} | {:.3} GBitOps | {:.1}s wall \
             ({:.0} tokens/s, exec fraction {:.0}%)",
            out.metric,
            out.gbitops,
            dt,
            tokens / dt,
            100.0 * out.exec_seconds / dt
        );
        // print a compact loss curve
        let h = &out.history;
        print!("loss curve: ");
        for i in (0..h.losses.len()).step_by((h.losses.len() / 10).max(1)) {
            print!("{:.2} ", h.losses[i].1);
        }
        println!("-> {:.2}", h.losses.last().unwrap().1);
        outs.push(out);
    }

    let rep = SweepReport::new("e2e transformer LM", "perplexity", false);
    let csv = cpt::results_dir().join("e2e_lm.csv");
    rep.write_curves_csv(&outs, &csv)?;
    println!("\nwrote per-step curves to {}", csv.display());

    // headline comparison
    let (cr, st) = (&outs[0], &outs[1]);
    println!(
        "\nCPT(CR) vs STATIC: perplexity {:.2} vs {:.2} at {:.0}% of the compute",
        cr.metric,
        st.metric,
        100.0 * cr.gbitops / st.gbitops
    );
    Ok(())
}

//! FP-Agg vs Q-Agg (paper §4.3, Fig 5): is GNN aggregation robust to low
//! precision? Trains the same GCN/SAGE with full-precision and quantized
//! aggregation at q_t = q_max = 8 and compares validation accuracy.
//!
//!   make artifacts && cargo run --release --example gnn_aggregation

use anyhow::Result;
use cpt::prelude::*;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;

    println!("aggregation ablation at static q_t = q_max = 8 (paper Fig 5)\n");
    for (fam, pair) in [
        ("GCN (OGBN-Arxiv stand-in)", ["gcn_fpagg", "gcn_qagg"]),
        ("GraphSAGE (OGBN-Products stand-in)", ["sage_fpagg", "sage_qagg"]),
    ] {
        println!("{fam}:");
        let mut accs = Vec::new();
        for name in pair {
            let model = rt.load_model(manifest.model(name)?)?;
            let out = cpt::coordinator::run_one(
                &model, name, "STATIC", 8.0, 0, 240, 8, 40, false,
            )?;
            println!(
                "  {:<12} accuracy {:.4}  ({:.3} GBitOps)",
                if name.ends_with("fpagg") { "FP-Agg" } else { "Q-Agg" },
                out.metric,
                out.gbitops
            );
            accs.push(out.metric);
        }
        let gap = accs[0] - accs[1];
        println!("  FP-Agg − Q-Agg = {gap:+.4}\n");
    }
    println!(
        "Paper finding: FP-Agg slightly ahead on the Arxiv-like graph;\n\
         near-parity on the Products-like graph (neighbor sampling truncates\n\
         the aggregation sum — footnote 4)."
    );
    Ok(())
}

//! Quickstart: train the MLP with the original CPT schedule (CR) and
//! compare against the static baseline.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use cpt::prelude::*;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let model = rt.load_model(manifest.model("mlp")?)?;

    for sched in ["CR", "RR", "STATIC"] {
        let out = cpt::coordinator::run_one(
            &model, "mlp", sched, 8.0, 0, 128, 8, 32, false,
        )?;
        println!(
            "{sched:<8} accuracy={:.4} GBitOps={:.4} exec={:.2}s",
            out.metric, out.gbitops, out.exec_seconds
        );
    }
    Ok(())
}

//! Schedule explorer: renders the paper's Figure 2 as ASCII, prints the
//! savings-group table, and shows how cycle count and q-range reshape a
//! schedule. Pure L3 — no artifacts needed.
//!
//!   cargo run --release --example schedule_explorer
//!
//! Policy-trace replay mode: instead of a precomputed schedule, drive an
//! adaptive precision policy (rust/src/policy/) against a synthetic loss
//! curve — decay, a long plateau, then slow progress — and plot the
//! realized q_t trace it emits, with its realized mean q and relative
//! cost:
//!
//!   cargo run --release --example schedule_explorer -- --policy loss_plateau
//!   cargo run --release --example schedule_explorer -- \
//!       --policy cost_governor:target=0.6

use anyhow::{Context, Result};
use cpt::prelude::*;
use cpt::schedule::{
    mean_relative_q_of_trace, relative_cost, relative_cost_of_trace,
};

/// ASCII-plot any q(t) trajectory over `total` steps.
fn plot_fn(q_of: impl Fn(usize) -> u32, total: usize, q_min: u32, q_max: u32) {
    let width = 72usize;
    let levels = (q_max - q_min + 1) as usize;
    let mut rows = vec![vec![' '; width]; levels];
    for col in 0..width {
        let t = col * (total - 1) / (width - 1);
        let q = q_of(t).clamp(q_min, q_max);
        let row = (q_max - q) as usize;
        rows[row][col] = '#';
    }
    for (i, row) in rows.iter().enumerate() {
        println!("  q={:>2} |{}", q_max - i as u32, row.iter().collect::<String>());
    }
    println!("       +{}", "-".repeat(width));
}

fn plot(s: &Schedule, total: usize, q_min: u32, q_max: u32) {
    plot_fn(|t| s.q_at(t), total, q_min, q_max);
}

/// The synthetic loss curve the replay feeds back: fast early progress,
/// a long mid-run plateau (where plateau policies switch), then slow
/// late improvement.
fn synthetic_loss(t: usize) -> f32 {
    let t = t as f32;
    let floor = 2.0 / (1.0 + 0.02 * 300.0);
    if t < 300.0 {
        2.0 / (1.0 + 0.02 * t)
    } else if t < 550.0 {
        floor
    } else {
        floor - 0.0003 * (t - 550.0)
    }
}

/// Replay an adaptive policy against the synthetic loss curve and plot
/// the realized trace.
fn replay_policy(spec_str: &str) -> Result<()> {
    let total = 800usize;
    let (q_min, q_max) = (3.0, 8.0);
    let spec = PolicySpec::parse(spec_str)?;
    let mut pol = spec.build_adaptive(q_min, q_max, total)?;
    let chunk = 8usize;
    let mut qs: Vec<u32> = Vec::with_capacity(total);
    let mut step = 0usize;
    while step < total {
        let k = chunk.min(total - step);
        for q in pol.q_chunk(step, k) {
            qs.push(q as u32);
        }
        let losses: Vec<f32> =
            (0..k).map(|i| synthetic_loss(step + i)).collect();
        pol.observe(ChunkFeedback::from_losses(step, &losses));
        step += k;
    }
    println!(
        "policy replay: {} over T={total}, q in [3, 8], chunk={chunk}",
        spec.canonical()
    );
    println!(
        "synthetic loss: decay until t=300, plateau until t=550, then slow \
         progress\n"
    );
    plot_fn(|t| qs[t.min(qs.len() - 1)], total, 3, 8);
    println!(
        "\nrealized: mean q/qmax {:.3}, relative cost {:.3} (vs static \
         q_max)",
        mean_relative_q_of_trace(&qs, q_max),
        relative_cost_of_trace(&qs, q_max)
    );
    println!(
        "(the same trace figures land in sweep CSVs as the mean_q / \
         realized_cost columns)"
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = args.iter().position(|a| a == "--policy") {
        let spec = args
            .get(i + 1)
            .context("--policy needs a value, e.g. loss_plateau")?;
        return replay_policy(spec);
    }

    let total = 800;
    let (q_min, q_max) = (3.0, 8.0);

    println!("CPT schedule suite (paper Fig 2), T={total}, q in [3, 8], n=8\n");
    println!(
        "{:<9} {:<10} {:>12} {:>10}",
        "schedule", "group", "mean q/qmax", "rel. cost"
    );
    for name in suite::suite_names() {
        let s = suite::by_name(name, q_min, q_max, total, 8)?;
        println!(
            "{:<9} {:<10} {:>12.3} {:>10.3}",
            name,
            group_of(name).label(),
            s.mean_relative_precision(total),
            relative_cost(&s, q_max, total)
        );
    }

    for name in ["CR", "CT", "RR", "RTH", "RTV", "ER"] {
        let s = suite::by_name(name, q_min, q_max, total, 8)?;
        println!(
            "\n{name} — {} profile, {} (group {})",
            name.chars().next().unwrap(),
            if name.len() == 2 && name.ends_with('R') {
                "repeated"
            } else {
                "triangular"
            },
            group_of(name).label()
        );
        plot(&s, total, 3, 8);
    }

    println!("\ncycle count effect on CR (n = 2, 4, 8):");
    for n in [2usize, 4, 8] {
        let s = suite::by_name("CR", q_min, q_max, total, n)?;
        println!("\n  n = {n}:");
        plot(&s, total, 3, 8);
    }

    println!("\ndeficit schedule (critical-period experiments, §5):");
    let d = Schedule::deficit(3.0, 8.0, 200, 500);
    plot(&d, total, 3, 8);

    println!(
        "\ntip: replay an adaptive policy's realized trace with \
         `-- --policy loss_plateau` or `-- --policy \
         cost_governor:target=0.6`"
    );
    Ok(())
}

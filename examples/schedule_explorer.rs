//! Schedule explorer: renders the paper's Figure 2 as ASCII, prints the
//! savings-group table, and shows how cycle count and q-range reshape a
//! schedule. Pure L3 — no artifacts needed.
//!
//!   cargo run --release --example schedule_explorer

use anyhow::Result;
use cpt::prelude::*;
use cpt::schedule::relative_cost;

fn plot(s: &Schedule, total: usize, q_min: u32, q_max: u32) {
    let width = 72usize;
    let levels = (q_max - q_min + 1) as usize;
    let mut rows = vec![vec![' '; width]; levels];
    for col in 0..width {
        let t = col * (total - 1) / (width - 1);
        let q = s.q_at(t).clamp(q_min, q_max);
        let row = (q_max - q) as usize;
        rows[row][col] = '#';
    }
    for (i, row) in rows.iter().enumerate() {
        println!("  q={:>2} |{}", q_max - i as u32, row.iter().collect::<String>());
    }
    println!("       +{}", "-".repeat(width));
}

fn main() -> Result<()> {
    let total = 800;
    let (q_min, q_max) = (3.0, 8.0);

    println!("CPT schedule suite (paper Fig 2), T={total}, q in [3, 8], n=8\n");
    println!(
        "{:<9} {:<10} {:>12} {:>10}",
        "schedule", "group", "mean q/qmax", "rel. cost"
    );
    for name in suite::suite_names() {
        let s = suite::by_name(name, q_min, q_max, total, 8)?;
        println!(
            "{:<9} {:<10} {:>12.3} {:>10.3}",
            name,
            group_of(name).label(),
            s.mean_relative_precision(total),
            relative_cost(&s, q_max, total)
        );
    }

    for name in ["CR", "CT", "RR", "RTH", "RTV", "ER"] {
        let s = suite::by_name(name, q_min, q_max, total, 8)?;
        println!(
            "\n{name} — {} profile, {} (group {})",
            name.chars().next().unwrap(),
            if name.len() == 2 && name.ends_with('R') {
                "repeated"
            } else {
                "triangular"
            },
            group_of(name).label()
        );
        plot(&s, total, 3, 8);
    }

    println!("\ncycle count effect on CR (n = 2, 4, 8):");
    for n in [2usize, 4, 8] {
        let s = suite::by_name("CR", q_min, q_max, total, n)?;
        println!("\n  n = {n}:");
        plot(&s, total, 3, 8);
    }

    println!("\ndeficit schedule (critical-period experiments, §5):");
    let d = Schedule::deficit(3.0, 8.0, 200, 500);
    plot(&d, total, 3, 8);
    Ok(())
}

//! Critical learning periods demo (paper §5, Fig 8 shape): apply a
//! low-precision deficit window at different points of GCN training and
//! watch where the damage is permanent.
//!
//!   make artifacts && cargo run --release --example critical_periods

use anyhow::Result;
use cpt::prelude::*;
use cpt::schedule::Schedule;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(cpt::artifacts_dir())?;
    let model = rt.load_model(manifest.model("gcn_qagg")?)?;
    let steps = 240usize;
    let window = 80usize;

    println!("GCN on SBM graph, {steps} steps, q_low=2 deficit window of {window} steps\n");

    // baseline: no deficit
    let base = run(&model, Schedule::static_q(8.0), steps)?;
    println!("no deficit:              accuracy {:.4}", base);

    // probing: the same-length window at different positions
    for start in [0usize, 40, 80, 120, 160] {
        let acc = run(
            &model,
            Schedule::deficit(2.0, 8.0, start, start + window),
            steps,
        )?;
        let delta = acc - base;
        println!(
            "deficit [{:>3}, {:>3}):      accuracy {:.4}  (Δ {:+.4})",
            start,
            start + window,
            acc,
            delta
        );
    }

    println!(
        "\nExpected shape (paper Fig 8 right): the earliest window hurts most;\n\
         later windows recover — low precision during the critical period\n\
         causes permanent damage."
    );
    Ok(())
}

fn run(model: &LoadedModel, schedule: Schedule, steps: usize) -> Result<f32> {
    let mut data = dataset_for("gcn_qagg", 42)?;
    let rec = recipe("gcn_qagg")?;
    let cfg = TrainConfig {
        total_steps: steps,
        q_bwd: 8.0,
        eval_every: 0,
        seed: 11,
        log_every: 4,
        verbose: false,
    };
    let mut t = Trainer::new(
        model,
        data.as_mut(),
        schedule,
        rec.lr_schedule(steps),
        cfg,
    );
    Ok(t.run()?.final_eval_metric().unwrap_or(f32::NAN))
}
